"""Quickstart: build a BigBird LM, train it, generate from it — in 2 minutes
on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.attention import AttentionSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M
from repro.serve import Engine

# --- 1. a BigBird attention spec: the paper's three components -------------
bigbird = AttentionSpec(
    kind="bigbird", causal=True,
    block_size=16,           # App. D blockification
    num_window_blocks=3,     # locality  (w)
    num_global_blocks=1,     # star graph (g) — the theory's key ingredient
    num_random_blocks=2,     # expander  (r)
    impl="blockified",       # paper-faithful XLA path ("pallas" on TPU)
)

# --- 2. a model using it ----------------------------------------------------
cfg = M.ModelConfig(
    name="quickstart", d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, attn=bigbird, dtype=jnp.float32,
    loss_chunk=128)

# --- 3. train ---------------------------------------------------------------
opt = S.make_optimizer(schedule="cosine", peak_lr=3e-3, warmup=10, total=60)
train_step = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                              batch_size=8, seed=0))

params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
for step in range(60):
    batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    state, metrics = train_step(state, batch)
    if step % 10 == 0 or step == 59:
        print(f"step {step:3d}  loss {float(metrics['loss']):.3f}  "
              f"lr {float(metrics['lr']):.1e}")

# --- 4. generate (bounded BigBird decode: O(1) cache reads per token) -------
# Engine.generate runs prefill + the whole greedy decode loop in ONE jitted
# call (lax.while_loop) — no per-token Python dispatch.
prompt = jnp.asarray(data.batch(999)["tokens"][:1, :64])
engine = Engine(cfg, state["params"], max_len=128, capacity=1)
out = engine.generate([prompt[0]], max_new=24)
print("generated:", out.sequences()[0])
print("OK — loss fell and the model generates; see examples/genomics_mlm.py "
      "and examples/summarize_encdec.py for the paper's applications.")
