"""Paper Sec. 5 — Genomics: DNA MLM pretraining + promoter-region prediction
(Tables 5 & 6), offline reproduction on a synthetic genome with planted
promoter motifs.

    PYTHONPATH=src python examples/genomics_mlm.py

Pipeline (mirrors App. F):
  1. synthesize a GRCh37-like genome with TATA-box/CpG promoter motifs,
  2. build a subword tokenizer (~the paper's 8.78 bp/token sentencepiece),
  3. MLM-pretrain a BigBird encoder over long DNA contexts,
  4. fine-tune a [CLS] head for promoter classification; report F1.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec
from repro.data import dna
from repro.launch import steps as S
from repro.models import model as M

t0 = time.time()
print("[1/4] synthesizing genome...")
genome, sites = dna.synthesize_genome(dna.GenomeConfig(length=400_000))
tok = dna.DnaTokenizer(genome, vocab_size=1024)
print(f"    genome=400kb, promoters={len(sites)}, vocab={tok.vocab_size}, "
      f"~{400_000/len(tok.encode(genome[:50_000]))/8:.1f} bp/token")

print("[2/4] MLM pretraining (BigBird encoder over DNA)...")
bigbird = AttentionSpec(kind="bigbird", causal=False, block_size=16,
                        num_window_blocks=3, num_global_blocks=1,
                        num_random_blocks=2, impl="blockified")
cfg = M.ModelConfig(name="dna", d_model=96, num_layers=3, num_heads=4,
                    num_kv_heads=4, d_ff=256, vocab_size=tok.vocab_size,
                    attn=bigbird, dtype=jnp.float32, loss_chunk=64)
opt = S.make_optimizer(schedule="cosine", peak_lr=2e-3, warmup=10, total=120)
ts = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
gen = dna.mlm_batches(genome, tok, batch=8, seq_len=256)
first = last = None
for step in range(120):
    batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
    state, m = ts(state, batch)
    if first is None:
        first = float(m["loss"])
    last = float(m["loss"])
bpc = last / np.log(2) / 8.78        # nats/token -> bits/char (Tab. 5 metric)
print(f"    MLM loss {first:.3f} -> {last:.3f}  (~{bpc:.3f} BPC)")

print("[3/4] promoter fine-tune (full-model, [CLS] head — paper App. F.2)...")
X, y = dna.promoter_dataset(genome, sites, tok, n_examples=512, frag=240,
                            seq_len=64)
# prepend [CLS] (paper: prediction from the CLS position)
X = np.concatenate([np.full((len(X), 1), tok.cls, np.int32), X[:, :-1]], 1)
Xt, yt = X[:384], y[:384]
Xe, ye = X[384:], y[384:]

clf = {"trunk": state["params"],
       "head": {"w": jnp.zeros((cfg.d_model, 2), jnp.float32),
                "b": jnp.zeros((2,), jnp.float32)}}


def clf_logits(clf, xb):
    h, _ = M.hidden_states(clf["trunk"], cfg, {"tokens": xb, "labels": xb})
    return h[:, 0].astype(jnp.float32) @ clf["head"]["w"] + clf["head"]["b"]


def clf_loss(clf, xb, yb):
    logits = clf_logits(clf, xb)
    onehot = jax.nn.one_hot(yb, 2)
    return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))


from repro.optim import optimizers as Opt, schedules
ft_opt = Opt.adamw(schedules.constant(5e-4), weight_decay=0.0)
ft_state = ft_opt.init(clf)


@jax.jit
def ft_step(clf, ft_state, step, xb, yb):
    l, g = jax.value_and_grad(clf_loss)(clf, xb, yb)
    clf, ft_state, _ = ft_opt.update(g, ft_state, clf, step)
    return clf, ft_state, l


step_ctr = jnp.zeros((), jnp.int32)
for epoch in range(8):
    perm = np.random.default_rng(epoch).permutation(len(Xt))
    for i in range(0, len(Xt), 32):
        sl = perm[i:i + 32]
        clf, ft_state, l = ft_step(clf, ft_state, step_ctr,
                                   jnp.asarray(Xt[sl]), jnp.asarray(yt[sl]))
        step_ctr = step_ctr + 1

print("[4/4] evaluating...")
pred = np.asarray(jnp.argmax(clf_logits(clf, jnp.asarray(Xe)), -1))
tp = int(((pred == 1) & (ye == 1)).sum())
fp = int(((pred == 1) & (ye == 0)).sum())
fn = int(((pred == 0) & (ye == 1)).sum())
prec = tp / max(tp + fp, 1)
rec = tp / max(tp + fn, 1)
f1 = 2 * prec * rec / max(prec + rec, 1e-9)
print(f"    promoter F1 = {f1:.3f}  (precision {prec:.3f}, recall {rec:.3f})"
      f"  [{time.time()-t0:.0f}s total]")
assert f1 > 0.8, "promoter classification should be strong on planted motifs"
print("OK — Sec. 5 pipeline reproduced end-to-end (synthetic genome).")
