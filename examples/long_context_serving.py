"""End-to-end serving driver: a ~25M-parameter BigBird LM serving BATCHED
requests with long prompts, demonstrating the bounded-decode property —
per-token cache reads are O((g+w+r)*b), independent of context length.

Runs both Engine modes:
  * `generate` — the fully-jitted loop (prefill + lax.while_loop decode);
  * `submit/step/drain` — slot-based continuous batching: requests with
    DIFFERENT prompt lengths admitted at different step boundaries share
    one decode step via per-slot positions.

Token accounting is exact: `generate(max_new=N)` emits N tokens = 1 from
prefill + N-1 decode steps, and tok/s is reported over the N-1 decode
steps (the old hand-rolled loop divided N tokens by N-1 steps' time).

    PYTHONPATH=src python examples/long_context_serving.py

Pass `--mesh DxM` (e.g. `--mesh 2x2` with
XLA_FLAGS=--xla_force_host_platform_device_count=8) to re-serve the same
requests over a (data, model) mesh — slots/KV pages shard over `data`, kv
heads over `model` — and check the sharded streams are bit-identical to
the unsharded ones (DESIGN.md §Mesh-parallel serving).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec
from repro.models import model as M
from repro.serve import Engine, Request, SamplingSpec

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", default=None, metavar="DxM",
                help="also serve over a (data, model) mesh, e.g. 2x2")
args = ap.parse_args()

bigbird = AttentionSpec(kind="bigbird", causal=True, block_size=64,
                        num_window_blocks=3, num_global_blocks=1,
                        num_random_blocks=2, impl="blockified")
cfg = M.ModelConfig(name="serve25m", d_model=256, num_layers=8, num_heads=8,
                    num_kv_heads=4, d_ff=1024, vocab_size=8192, attn=bigbird,
                    dtype=jnp.float32, loss_chunk=256)

params = M.init(cfg, jax.random.PRNGKey(0))
n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
print(f"[serve] model: {n/1e6:.1f}M params, bounded BigBird decode")

B, PROMPT, GEN, MAXLEN = 4, 1024, 48, 2048
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 4,
                             cfg.vocab_size)
engine = Engine(cfg, params, max_len=MAXLEN, capacity=B)

# --- mode 1: fully-jitted batched generate --------------------------------
t0 = time.time()
out = engine.generate([p for p in prompts], max_new=1)   # prefill + 1st tok
t_first = time.time() - t0
print(f"[serve] cold prefill {B}x{PROMPT} + first token: {t_first:.2f}s "
      "(compile included)")

engine.generate([p for p in prompts], max_new=GEN)        # warm the GEN loop
t0 = time.time()
engine.generate([p for p in prompts], max_new=1)          # warm: TTFT
t_prefill = time.time() - t0
t0 = time.time()
out = engine.generate([p for p in prompts], max_new=GEN)
t_total = time.time() - t0
t_dec = max(t_total - t_prefill, 1e-9)       # exactly GEN-1 decode steps
steps = GEN - 1
print(f"[serve] warm TTFT {t_prefill:.2f}s ({B*PROMPT/t_prefill:.0f} prompt "
      f"tok/s); {B}x{GEN} tokens in {t_total:.2f}s; decode {B*steps} tokens "
      f"in {t_dec:.2f}s ({B*steps/t_dec:.1f} tok/s, "
      f"{t_dec/steps*1e3:.0f} ms/step)")

# --- mode 2: paged continuous batching, heterogeneous prompt lengths ------
# prompts share a 64-token "system prefix" covering the global block, so
# co-resident requests map the same physical prefix pages (admitted once)
sys_prefix = np.asarray(prompts[0, :64])
lens = [1024, 700, 333, 901]


def make_reqs():
    return [Request(prompt=np.concatenate([sys_prefix,
                                           np.asarray(prompts[i, :lens[i]])]),
                    max_new_tokens=16 + 8 * i, sampling=SamplingSpec(seed=i))
            for i in range(B)]


reqs = make_reqs()
engine.submit(reqs[0]); engine.submit(reqs[1])
engine.step()                                  # 0 and 1 in flight...
engine.submit(reqs[2]); engine.submit(reqs[3])
results = engine.drain()                       # ...2 and 3 join mid-stream
for r in results:
    print(f"[serve] req{r.request_id} prompt={r.prompt_len:4d} "
          f"-> {len(r.tokens)} tokens ({r.finish_reason}); "
          f"{r.pages_used} pages ({r.shared_prefix_pages} shared)")

# paged-pool accounting: pages are allocated per request, not reserved at
# capacity x max_len, and shared global-prefix pages are admitted once
st = engine.stats()
slot_bytes = engine.pool.max_pages * st.kv_bytes_per_page
mean_pages = np.mean([r.pages_used for r in results])
print(f"[serve] page pool: {st.page_size}-token pages, peak "
      f"{st.peak_pages_in_use}/{st.num_pages} in use; prefix hits "
      f"{st.prefix_hits} ({st.prefix_pages_shared} pages admitted once)")
print(f"[serve] KV bytes/request: {mean_pages * st.kv_bytes_per_page/2**20:.1f}"
      f" MiB paged vs {slot_bytes/2**20:.1f} MiB slot-contiguous "
      f"({(1 - mean_pages / engine.pool.max_pages) * 100:.0f}% reclaimed)")

# bounded-read property: per-token attention reads (g+w+r)*b keys per layer,
# independent of the 1024-token context
reads = (1 + 3 + 2) * 64
print(f"[serve] per-token cache reads/layer: {reads} keys "
      f"(vs {PROMPT} for full attention — {PROMPT/reads:.1f}x fewer)")

# --- mode 3 (opt-in): mesh-parallel serving, bit-identical streams --------
if args.mesh:
    from repro.serve import mesh as Mx
    t0 = time.time()
    meng = Engine(cfg, params, max_len=MAXLEN, capacity=B,
                  mesh=Mx.parse_mesh(args.mesh))
    for r in make_reqs():
        meng.submit(r)
    sharded = meng.drain()
    mst = meng.stats()
    model_shards = int(args.mesh.lower().split("x")[1])
    print(f"[serve] mesh {args.mesh}: {sum(len(r.tokens) for r in sharded)} "
          f"tokens in {time.time()-t0:.2f}s (compile included); "
          f"{mst.kv_bytes_per_shard/2**20:.1f} MiB KV per data shard, "
          f"kv heads split {model_shards}-way")
    by_id = {r.request_id: r.tokens for r in results}
    assert all(r.tokens == by_id[r.request_id] for r in sharded), \
        "sharded streams diverged from the replicated run"
    print(f"[serve] mesh {args.mesh} streams bit-identical to unsharded OK")

print("OK — batched long-context serving with paged bounded decode.")
