"""End-to-end serving driver: a ~25M-parameter BigBird LM serving BATCHED
requests with long prompts, demonstrating the bounded-decode property —
per-token cache reads are O((g+w+r)*b), independent of context length.

    PYTHONPATH=src python examples/long_context_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec
from repro.models import decode as D
from repro.models import model as M

bigbird = AttentionSpec(kind="bigbird", causal=True, block_size=64,
                        num_window_blocks=3, num_global_blocks=1,
                        num_random_blocks=2, impl="blockified")
cfg = M.ModelConfig(name="serve25m", d_model=256, num_layers=8, num_heads=8,
                    num_kv_heads=4, d_ff=1024, vocab_size=8192, attn=bigbird,
                    dtype=jnp.float32, loss_chunk=256)

params = M.init(cfg, jax.random.PRNGKey(0))
n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
print(f"[serve] model: {n/1e6:.1f}M params, bounded BigBird decode")

B, PROMPT, GEN, MAXLEN = 4, 1024, 48, 2048
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 4,
                            cfg.vocab_size)

prefill = jax.jit(lambda p, b: D.prefill(p, cfg, b, MAXLEN))
step = jax.jit(lambda p, c, t, i: D.decode_step(p, cfg, c, t, i))

t0 = time.time()
logits, cache = jax.block_until_ready(
    prefill(params, {"tokens": prompt, "labels": prompt}))
t_prefill = time.time() - t0
print(f"[serve] prefill {B}x{PROMPT} tokens: {t_prefill:.2f}s "
      f"({B*PROMPT/t_prefill:.0f} tok/s)")

tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
t0 = time.time()
outs = [tok]
for i in range(GEN - 1):
    logits, cache = step(params, cache, tok, PROMPT + i)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs.append(tok)
jax.block_until_ready(tok)
t_dec = time.time() - t0
print(f"[serve] decoded {B}x{GEN} tokens: {t_dec:.2f}s "
      f"({B*GEN/t_dec:.1f} tok/s, {t_dec/GEN*1e3:.0f} ms/step batched)")

# bounded-read property: per-token attention reads (g+w+r)*b keys per layer,
# independent of the 1024-token context
reads = (1 + 3 + 2) * 64
print(f"[serve] per-token cache reads/layer: {reads} keys "
      f"(vs {PROMPT} for full attention — {PROMPT/reads:.1f}x fewer)")
print("OK — batched long-context serving with bounded decode.")
