"""Paper Sec. 4.1 — document summarization with a SPARSE ENCODER and a full
decoder (the BigBird-RoBERTa/Pegasus recipe).

Task: lead-summarization — the summary is the document's lead (first S_DEC
tokens), the classic "Lead" baseline of the summarization literature
(paper Tab. 20 row 1).  The decoder must cross-attend into the
BigBird-encoded document with monotone alignment; teacher-forced loss
falls well below the unigram baseline within the CPU budget and keeps
dropping (full convergence needs more steps than a CPU affords — the
machinery, not the wall-clock, is the point here).

    PYTHONPATH=src python examples/summarize_encdec.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec
from repro.launch import steps as S
from repro.models import model as M
from repro.serve import Engine

S_ENC, S_DEC, V, BOS = 128, 16, 256, 5
STEPS = 800
t0 = time.time()

sparse_encoder = AttentionSpec(kind="bigbird", causal=False, block_size=16,
                               num_window_blocks=3, num_global_blocks=1,
                               num_random_blocks=1, impl="blockified")
cfg = M.ModelConfig(name="summ", kind="encdec", d_model=64, num_layers=2,
                    enc_layers=2, num_heads=4, num_kv_heads=4, d_ff=128,
                    vocab_size=V, dec_len=S_DEC, enc_attn=sparse_encoder,
                    dtype=jnp.float32, scan_layers=False, remat="none",
                    loss_chunk=16, frontend="audio")


def make_batch(step, B=16):
    rng = np.random.default_rng(step)
    doc = rng.integers(8, V, size=(B, S_ENC)).astype(np.int32)
    tgt = doc[:, :S_DEC]
    dec_in = np.concatenate([np.full((B, 1), BOS), tgt[:, :-1]],
                            axis=1).astype(np.int32)
    return doc, dec_in, tgt


opt = S.make_optimizer(schedule="constant", peak_lr=5e-3)
ts = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}

print(f"[summarize] BigBird encoder ({S_ENC}) -> full decoder ({S_DEC})")
first = None
for step in range(STEPS):
    doc, dec_in, tgt = make_batch(step)
    frames = jnp.take(state["params"]["embed"]["table"], jnp.asarray(doc),
                      axis=0)
    batch = {"frames": frames, "tokens": jnp.asarray(dec_in),
             "labels": jnp.asarray(tgt)}
    state, m = ts(state, batch)
    if first is None:
        first = float(m["loss"])
    if step % 100 == 0 or step == STEPS - 1:
        print(f"  step {step:3d} loss {float(m['loss']):.3f}", flush=True)
last = float(m["loss"])
assert last < first - 1.0, "teacher-forced loss should fall substantially"

# held-out: teacher-forced token accuracy + incremental greedy decode
doc, dec_in, tgt = make_batch(999_999, B=8)
frames = jnp.take(state["params"]["embed"]["table"], jnp.asarray(doc), axis=0)
batch = {"frames": frames, "tokens": jnp.asarray(dec_in),
         "labels": jnp.asarray(tgt)}
tf_logits = M.logits_fn(state["params"], cfg, batch)
tf_acc = float((jnp.argmax(tf_logits, -1) == jnp.asarray(tgt)).mean())

# incremental greedy decode from BOS via the Engine: encoder runs once in
# prefill, the full-attention decoder loop runs jitted (lax.while_loop)
engine = Engine(cfg, state["params"], capacity=8)   # max_len -> cfg.dec_len
out = engine.generate([np.full((1,), BOS, np.int32)] * 8, max_new=S_DEC,
                      frames=frames)
greedy_acc = float((out.tokens == tgt).mean())

print(f"[summarize] loss {first:.2f} -> {last:.2f}; held-out teacher-forced "
      f"acc {tf_acc:.2%}, greedy acc {greedy_acc:.2%} [{time.time()-t0:.0f}s]")
print("OK — sparse encoder + full decoder (paper's summarization recipe): "
      "training converging, prefill+incremental decode exercised.")
