"""Async streaming client against the serving front-end (AsyncEngine).

A ~1M-parameter BigBird LM served through `repro.serve.AsyncEngine`,
exercising the full front-end contract from the client side:

  * per-request async token streams — `async for tok in session` yields
    each token the moment it crosses the device boundary, interleaved
    across concurrently-resident requests;
  * priority admission — a late high-priority request reaches a slot
    before earlier low-priority ones when the engine is saturated;
  * TTFT deadlines — a request whose deadline lapses before its first
    token resolves with finish_reason="deadline_exceeded" (never a hang);
  * cancellation — `session.cancel()` aborts cleanly mid-stream, the
    Result carries exactly the streamed prefix, and the engine's page
    pool drains back to empty.

Every stream is bit-identical to what the synchronous `Engine.drain`
would produce for the same request (DESIGN.md §Async front-end), so the
async layer is pure scheduling: it never changes model outputs.

    PYTHONPATH=src python examples/streaming_client.py
"""

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec
from repro.models import model as M
from repro.serve import AsyncEngine, Engine, SamplingSpec

bigbird = AttentionSpec(
    kind="bigbird",
    causal=True,
    block_size=16,
    num_window_blocks=3,
    num_global_blocks=1,
    num_random_blocks=1,
)
cfg = M.ModelConfig(
    name="stream-demo",
    d_model=64,
    num_layers=2,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    attn=bigbird,
    dtype=jnp.float32,
    loss_chunk=64,
)
params = M.init(cfg, jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
prompts = [
    rng.integers(4, cfg.vocab_size, size=n).astype(np.int32) for n in (96, 48, 80, 33)
]

# dispatch_depth=2 keeps an extra engine step in flight while tokens are
# routed to streams — decode throughput survives the asyncio hop
engine = Engine(cfg, params, max_len=192, capacity=2, dispatch_depth=2)


async def consume(name, sess, t0, cancel_after=None):
    got = []
    async for tok in sess:
        got.append(tok)
        print(f"[{time.time() - t0:5.2f}s] {name:>8} -> {tok}", flush=True)
        if cancel_after is not None and len(got) >= cancel_after:
            sess.cancel()
    r = await sess.result()
    assert list(r.tokens) == got, "stream and Result must agree"
    print(
        f"[{time.time() - t0:5.2f}s] {name:>8} done: {r.finish_reason}, "
        f"{len(r.tokens)} tokens, ttft {r.ttft_s:.2f}s",
        flush=True,
    )
    return r


async def main():
    front = AsyncEngine(engine)
    t0 = time.time()

    # two requests saturate capacity=2; tokens interleave across streams
    warm = await front.submit(prompts[0], 6, sampling=SamplingSpec(seed=0))
    a = await consume("warmup", warm, t0)
    assert a.finish_reason == "length"

    tasks = []
    for i in (0, 1):
        sess = await front.submit(prompts[i], 10, sampling=SamplingSpec(seed=i))
        tasks.append(asyncio.ensure_future(consume(f"req{i}", sess, t0)))
    await asyncio.sleep(0)

    # the engine is full: "rush" outranks "batch" in the admission queue
    # and reaches a freed slot first even though it arrived later
    sp2, sp3 = SamplingSpec(seed=2), SamplingSpec(seed=3)
    batch = await front.submit(prompts[2], 8, priority=0, sampling=sp2)
    rush = await front.submit(prompts[3], 8, priority=5, sampling=sp3)
    # an impatient request: 1 ms TTFT budget it cannot possibly meet
    doomed = await front.submit(prompts[2], 8, deadline_s=0.001)
    tasks.append(asyncio.ensure_future(consume("batch", batch, t0)))
    tasks.append(asyncio.ensure_future(consume("rush", rush, t0)))

    r = await doomed.result()
    assert r.finish_reason == "deadline_exceeded" and r.tokens == []
    print(
        f"[{time.time() - t0:5.2f}s]   doomed done: {r.finish_reason} "
        "(typed result, no hang)",
        flush=True,
    )

    results = await asyncio.gather(*tasks)
    assert rush.request_id > batch.request_id  # arrived later...
    assert results[3].ttft_s <= results[2].ttft_s  # ...served first

    # cancellation mid-stream: stream ends, prefix preserved, pages freed
    # (with dispatch_depth=2 a couple of already-in-flight tokens may land
    # before the abort applies at the next step boundary)
    late = await front.submit(prompts[1], 24, sampling=SamplingSpec(seed=9))
    r = await consume("cancelme", late, t0, cancel_after=3)
    assert r.finish_reason == "aborted" and 3 <= len(r.tokens) < 24

    await front.close()
    pool = engine.pool
    assert pool.pages_in_use == 0 and pool.pages_reserved == 0
    print("OK — streamed, prioritized, deadlined and cancelled; pool empty.")


if __name__ == "__main__":
    asyncio.run(main())
