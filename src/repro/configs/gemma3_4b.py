"""gemma3-4b [dense] — 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
Every 6th layer is global attention; the rest are 1024-token sliding-window
(the BigBird window component, block-granular).  34 is not a multiple of 6,
so the layer list is written out explicitly (scan disabled; 34 distinct
layers — matches how the released model ends on local layers).
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.core.attention import AttentionSpec
from repro.models.model import LayerSpec, ModelConfig

notes = "[hf:google/gemma-3-1b-pt; unverified] — 5 local : 1 global, SWA=1024"

LOCAL = AttentionSpec(kind="window", causal=True, block_size=64,
                      window_tokens=1024, impl="blockified")

_pattern = tuple(
    LayerSpec(kind="attn", attn=(FULL_CAUSAL if (i + 1) % 6 == 0 else LOCAL))
    for i in range(34))

CONFIG = ModelConfig(
    name="gemma3-4b",
    d_model=2560, num_layers=34, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    layer_pattern=_pattern,
    attn=FULL_CAUSAL, tie_embeddings=True,
    rope_theta=1e6,
    dtype=jnp.bfloat16, remat="full", scan_layers=False, max_seq=131072,
)

_smoke_pattern = tuple(
    LayerSpec(kind="attn", attn=(
        FULL_CAUSAL if (i + 1) % 6 == 0 else
        dataclasses.replace(LOCAL, block_size=16, window_tokens=32)))
    for i in range(6))

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=6, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, layer_pattern=_smoke_pattern,
    dtype=jnp.float32, remat="none", loss_chunk=64, max_seq=256)
