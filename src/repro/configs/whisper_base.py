"""whisper-base [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  Encoder consumes
precomputed frame embeddings (B, S, 512) from the stub frontend; decoder is
causal with cross-attention.  This is the paper's own seq2seq recipe
(Sec. 4.1): sparse BigBird encoder + full decoder — enabled for the
long-context cells via bigbird_variant.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.core.attention import AttentionSpec
from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2212.04356; unverified] — 6L+6L enc-dec, conv frontend stubbed"

CONFIG = ModelConfig(
    name="whisper-base", kind="encdec",
    d_model=512, num_layers=6, enc_layers=6,
    num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865, dec_len=448,
    layer_pattern=(LayerSpec(kind="attn"),),
    attn=FULL_CAUSAL,
    enc_attn=AttentionSpec(kind="full", causal=False),
    tie_embeddings=True,
    dtype=jnp.bfloat16, remat="full", scan_layers=True,
    frontend="audio", max_seq=32768,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, enc_layers=2, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, dec_len=32, max_seq=256,
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=32)
