"""Architecture registry + assigned input shapes.

10 assigned archs x 4 shapes = 40 dry-run cells, plus the paper's own
bigbird-base config.  ``input_specs`` returns ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs import common
from repro.models.model import ModelConfig

_MODULES = {
    "internvl2-26b": "internvl2_26b",
    "whisper-base": "whisper_base",
    "minicpm-2b": "minicpm_2b",
    "gemma3-4b": "gemma3_4b",
    "yi-6b": "yi_6b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "grok-1-314b": "grok_1_314b",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "bigbird-base": "bigbird_base",
    "bigbird-draft": "bigbird_draft",
}

ARCHS = tuple(k for k in _MODULES
              if k not in ("bigbird-base", "bigbird-draft"))

# assigned LM shapes: (seq_len, global_batch, mode)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def _module(name: str):
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def optimizer_for(name: str) -> str:
    return getattr(_module(name), "optimizer", "adamw")


def schedule_for(name: str) -> str:
    return getattr(_module(name), "schedule", "cosine")


def config_for_cell(name: str, shape: str) -> ModelConfig:
    """Config for an (arch, shape) dry-run cell.

    long_500k swaps quadratic attention for the BigBird pattern
    (DESIGN.md §Arch-applicability); all other cells use the reference config.
    """
    cfg = get(name)
    if shape == "long_500k" and not common.is_subquadratic(cfg):
        cfg = common.bigbird_variant(cfg)
    return cfg


def input_specs(name: str, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: token batch (+ frontend stubs).
    decode: (cache, tokens, pos) — cache shapes via models.decode.cache_spec.
    Returns (mode, dict | tuple) — see launch.steps for consumption.
    """
    from repro.models import decode as Dec

    cfg = config_for_cell(name, shape)
    seq, batch, mode = SHAPES[shape]
    i32 = jnp.int32

    if mode in ("train", "prefill"):
        if cfg.kind == "encdec":
            specs = {
                "frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.dtype),
                "tokens": jax.ShapeDtypeStruct((batch, cfg.dec_len), i32),
                "labels": jax.ShapeDtypeStruct((batch, cfg.dec_len), i32),
            }
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            }
            if cfg.frontend == "patch":
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (batch, cfg.frontend_len, cfg.d_model), cfg.dtype)
        return mode, specs

    # decode: one new token against a seq-length cache
    if cfg.kind == "encdec":
        cache = Dec.cache_spec(cfg, batch, cfg.dec_len, enc_len=seq)
    else:
        cache = Dec.cache_spec(cfg, batch, seq)
    return mode, {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((batch, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def all_cells():
    return [(a, s) for a in ARCHS for s in SHAPES]
