"""Shared helpers for architecture configs.

Each assigned arch file defines:
  CONFIG — the exact published configuration (full scale),
  SMOKE  — a reduced same-family config for CPU smoke tests,
  notes  — provenance string.

`long_500k` policy (DESIGN.md §Arch-applicability): archs whose reference
attention is quadratic get a **BigBird variant** for that cell —
`bigbird_variant(cfg)` swaps every full-attention layer to the paper's
pattern (b=64, w=3, g=2, r=3, causal) and leaves everything else identical.
"""
from __future__ import annotations

import dataclasses

from repro.core.attention import AttentionSpec
from repro.models.model import ModelConfig

FULL_CAUSAL = AttentionSpec(kind="full", causal=True)

# the paper's base sparse pattern (Tab. 8: block 64, g=2b, w=3b, r=3b);
# impl="pallas" — the fused kernel trains end-to-end via its custom_vjp
BIGBIRD_CAUSAL = AttentionSpec(
    kind="bigbird", causal=True, block_size=64,
    num_window_blocks=3, num_global_blocks=2, num_random_blocks=3,
    impl="pallas")

BIGBIRD_ENCODER = dataclasses.replace(BIGBIRD_CAUSAL, causal=False)


def bigbird_variant(cfg: ModelConfig) -> ModelConfig:
    """Swap full-attention layers to the BigBird pattern (long-context cells).

    encdec: ONLY the encoder goes sparse — the decoder (and its short
    self-attention) stays full, exactly the paper's seq2seq recipe (§4.1:
    "sparse attention mechanism for the encoder and full self-attention for
    the decoder").
    """
    if cfg.kind == "encdec":
        if cfg.enc_attn is None or cfg.enc_attn.kind == "full":
            return dataclasses.replace(cfg, enc_attn=BIGBIRD_ENCODER)
        return cfg

    def swap(spec):
        if spec is None or spec.kind == "full":
            return dataclasses.replace(
                BIGBIRD_CAUSAL if (spec is None or spec.causal) else BIGBIRD_ENCODER)
        return spec

    pattern = tuple(
        dataclasses.replace(ls, attn=swap(ls.attn)) if ls.kind == "attn" else ls
        for ls in cfg.layer_pattern)
    new = dataclasses.replace(cfg, layer_pattern=pattern)
    if cfg.attn.kind == "full":
        new = dataclasses.replace(new, attn=swap(cfg.attn))
    return new


def with_attn_impl(cfg: ModelConfig, impl: str) -> ModelConfig:
    """Rewrite every sparse AttentionSpec (bigbird/window) to use ``impl``.

    Used by the trainer's --impl flag: "pallas" (fused kernels, the default
    production path), "blockified" (paper-faithful XLA), "reference" (dense
    oracle, tiny shapes only).  Full-attention specs are left untouched.
    """
    def swap(spec):
        if spec is not None and spec.kind in ("bigbird", "window"):
            return dataclasses.replace(spec, impl=impl)
        return spec

    pattern = tuple(
        dataclasses.replace(ls, attn=swap(ls.attn)) if ls.kind == "attn" else ls
        for ls in cfg.layer_pattern)
    new = dataclasses.replace(cfg, layer_pattern=pattern, attn=swap(cfg.attn))
    if getattr(cfg, "enc_attn", None) is not None:
        new = dataclasses.replace(new, enc_attn=swap(cfg.enc_attn))
    return new


def with_attn_pattern(cfg: ModelConfig, pattern: str) -> ModelConfig:
    """Rewrite every BigBird AttentionSpec to use pattern policy ``pattern``.

    Used by the launchers' --pattern flag: "bigbird" (paper layout, the
    default), "importance" (Smart Bird-style scored block selection),
    "littlebird" (sliding window + packed globals).  Window and
    full-attention specs are left untouched — SWA is the window component
    alone and has no policy choice to make.
    """
    def swap(spec):
        if spec is not None and spec.kind == "bigbird":
            return dataclasses.replace(spec, pattern=pattern)
        return spec

    layers = tuple(
        dataclasses.replace(ls, attn=swap(ls.attn)) if ls.kind == "attn" else ls
        for ls in cfg.layer_pattern)
    new = dataclasses.replace(cfg, layer_pattern=layers, attn=swap(cfg.attn))
    if getattr(cfg, "enc_attn", None) is not None:
        new = dataclasses.replace(new, enc_attn=swap(cfg.enc_attn))
    return new


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True if no layer in the reference config does full attention."""
    def full(spec):
        return spec is None or spec.kind == "full"

    if cfg.kind == "encdec" and full(cfg.enc_attn):
        return False
    for ls in cfg.layer_pattern:
        if ls.kind == "attn" and full(ls.attn if ls.attn else cfg.attn):
            return False
    return True
