"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.  SWA (mistral-style,
4096-token window) is exactly the BigBird window component at block
granularity (DESIGN.md §Arch-applicability).
"""
import dataclasses

import jax.numpy as jnp

from repro.core.attention import AttentionSpec
from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2401.16818; hf] — SWA 4096 == BigBird window component"

SWA = AttentionSpec(kind="window", causal=True, block_size=64,
                    window_tokens=4096, impl="blockified")

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    d_model=2560, num_layers=24, num_heads=32, num_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000,
    layer_pattern=(LayerSpec(kind="attn", attn=SWA),),
    attn=SWA, tie_embeddings=False,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=16384,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_pattern=(LayerSpec(kind="attn", attn=dataclasses.replace(
        SWA, block_size=16, window_tokens=48)),),
    attn=dataclasses.replace(SWA, block_size=16, window_tokens=48),
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64,
    max_seq=256)
