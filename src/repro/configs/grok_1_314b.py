"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.
Optimizer recipe: Adafactor (optim state must fit 16 GB/chip at 314B).
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.models.layers import MoEConfig
from repro.models.model import LayerSpec, ModelConfig

notes = "[hf:xai-org/grok-1; unverified] — MoE 8e top-2; adafactor recipe"
optimizer = "adafactor"

CONFIG = ModelConfig(
    name="grok-1-314b",
    d_model=6144, num_layers=64, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    layer_pattern=(LayerSpec(kind="attn", moe=True),),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32768),
    attn=FULL_CAUSAL, tie_embeddings=False,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=8192,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, moe=MoEConfig(num_experts=4, top_k=2, d_ff=128),
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64,
    max_seq=256)
