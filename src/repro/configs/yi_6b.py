"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2403.04652; hf]"

CONFIG = ModelConfig(
    name="yi-6b",
    d_model=4096, num_layers=32, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    layer_pattern=(LayerSpec(kind="attn"),),
    attn=FULL_CAUSAL, tie_embeddings=False,
    rope_theta=5e6,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, dtype=jnp.float32, scan_layers=False,
    remat="none", loss_chunk=64, max_seq=256)
