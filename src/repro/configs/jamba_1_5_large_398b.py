"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Period of 8 layers: attention at position 4, Mamba elsewhere; MoE on odd
positions (every other layer).  BigBird applies to the 1-in-8 attention
layers for the long-context cells; Mamba layers are already linear.
Optimizer recipe: Adafactor (398B optimizer state must fit).
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.models.layers import MoEConfig
from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2403.19887; hf] — 1:7 attn:mamba, MoE every 2nd layer"
optimizer = "adafactor"

_pattern = tuple(
    LayerSpec(kind=("attn" if i == 4 else "mamba"), moe=(i % 2 == 1))
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    d_model=8192, num_layers=72, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    layer_pattern=_pattern,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    attn=FULL_CAUSAL, tie_embeddings=False,
    mamba_d_state=16, mamba_expand=2, mamba_conv=4,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=262144,
)

_smoke_pattern = tuple(
    LayerSpec(kind=("attn" if i == 2 else "mamba"), moe=(i % 2 == 1))
    for i in range(4))

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=4, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, layer_pattern=_smoke_pattern,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=128),
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64,
    max_seq=256)
