"""internvl2-26b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The ViT frontend is a STUB: input_specs provide precomputed patch embeddings
(B, 256, d_model) spliced over the first 256 token positions.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2404.16821; hf] — LM backbone exact; ViT stubbed per assignment"

CONFIG = ModelConfig(
    name="internvl2-26b",
    d_model=6144, num_layers=48, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    layer_pattern=(LayerSpec(kind="attn"),),
    attn=FULL_CAUSAL,
    rope_theta=1e6, tie_embeddings=False,
    dtype=jnp.bfloat16, remat="full", scan_layers=True,
    frontend="patch", frontend_len=256, max_seq=32768,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, frontend_len=16, max_seq=256,
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64)
