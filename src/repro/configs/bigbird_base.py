"""BigBird-base — the paper's own pretraining configuration (Tab. 8).

12L d_model=768 12H d_ff=3072 vocab=50358, seq 4096, MLM objective,
block 64, g = 2 blocks (ITC), w = 3 blocks, r = 3 blocks.
BIGBIRD-ETC variant prepends 256 learned global tokens (g_etc).
"""
import dataclasses

import jax.numpy as jnp

from repro.core.attention import AttentionSpec
from repro.models.model import LayerSpec, ModelConfig

notes = "paper Tab. 8 (BIGBIRD-ITC-base); MLM objective"

# impl="pallas": the fused kernel is the end-to-end training path (it has a
# custom_vjp backward — see kernels/ops.py); "blockified" remains the
# paper-faithful XLA baseline used by parity tests and ablations.
ITC = AttentionSpec(kind="bigbird", causal=False, block_size=64,
                    num_window_blocks=3, num_global_blocks=2,
                    num_random_blocks=3, impl="pallas")

CONFIG = ModelConfig(
    name="bigbird-base",
    d_model=768, num_layers=12, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=50358,
    layer_pattern=(LayerSpec(kind="attn"),),
    attn=ITC, tie_embeddings=True,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=dataclasses.replace(ITC, block_size=16, num_window_blocks=3,
                             num_global_blocks=1, num_random_blocks=1),
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64,
    max_seq=256)
