"""llama4-scout-17b-a16e [moe] — MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.models.layers import MoEConfig
from repro.models.model import LayerSpec, ModelConfig

notes = "[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — all-MoE, top-1"

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    d_model=5120, num_layers=48, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    layer_pattern=(LayerSpec(kind="attn", moe=True),),
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192),
    attn=FULL_CAUSAL, tie_embeddings=False,
    rope_theta=5e5,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=8192,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, moe=MoEConfig(num_experts=4, top_k=1, d_ff=128),
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64,
    max_seq=256)
