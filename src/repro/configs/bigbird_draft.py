"""BigBird-draft — a small causal BigBird LM for speculative drafting.

A ~4-layer, quarter-width sibling of bigbird-base used as the `ModelDraft`
provider in the speculative-decoding subsystem (serve/spec.py): it drafts
k greedy tokens per verify round over its own slot-contiguous cache.  The
draft shares the target's vocabulary (a hard requirement — acceptance
compares token ids) and keeps the same pattern block size so its bounded
decode stays O((g+w+r)·b) per token too; every other dimension is shrunk
for draft-side latency, since drafting sits on the serving critical path.
"""
import dataclasses

import jax.numpy as jnp

from repro.core.attention import AttentionSpec
from repro.models.model import LayerSpec, ModelConfig

notes = "speculative draft model for bigbird-base serving (beyond-paper)"

DRAFT_ATTN = AttentionSpec(kind="bigbird", causal=True, block_size=64,
                           num_window_blocks=3, num_global_blocks=2,
                           num_random_blocks=3, impl="blockified")

CONFIG = ModelConfig(
    name="bigbird-draft",
    d_model=192, num_layers=4, num_heads=4, num_kv_heads=4, head_dim=48,
    d_ff=768, vocab_size=50358,
    layer_pattern=(LayerSpec(kind="attn"),),
    attn=DRAFT_ATTN, tie_embeddings=True,
    dtype=jnp.bfloat16, remat="none", scan_layers=False, max_seq=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=32, num_layers=1, num_heads=2, num_kv_heads=2,
    head_dim=16, d_ff=64, vocab_size=512,
    attn=dataclasses.replace(DRAFT_ATTN, block_size=16, num_window_blocks=3,
                             num_global_blocks=1, num_random_blocks=1),
    dtype=jnp.float32, loss_chunk=64, max_seq=256)
