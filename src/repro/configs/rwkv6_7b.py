"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf].

32L d_model=4096 d_ff=14336 vocab=65536.  BigBird is inapplicable (no
attention graph to sparsify — DESIGN.md §Arch-applicability); the WKV6
recurrence has its own Pallas kernel (kernels/wkv6.py).  Natively O(n):
long_500k runs the reference config.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2404.05892; hf] — attention-free; BigBird inapplicable"

CONFIG = ModelConfig(
    name="rwkv6-7b",
    d_model=4096, num_layers=32, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    layer_pattern=(LayerSpec(kind="rwkv"),),
    rwkv_head_dim=64, tie_embeddings=False,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=524288,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_layers=2, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, rwkv_head_dim=16,
    dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64,
    max_seq=256)
