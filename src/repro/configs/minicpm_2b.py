"""minicpm-2b [dense] — llama-like, WSD schedule [arXiv:2404.06395; hf].

40L d_model=2304 36H (kv=36, i.e. MHA) d_ff=5760 vocab=122753.
The WSD (warmup-stable-decay) schedule lives in optim/schedules.py and is
selected by the training recipe for this arch.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.common import FULL_CAUSAL
from repro.models.model import LayerSpec, ModelConfig

notes = "[arXiv:2404.06395; hf] — arch=llama-like; WSD schedule in optim"
schedule = "wsd"

CONFIG = ModelConfig(
    name="minicpm-2b",
    d_model=2304, num_layers=40, num_heads=36, num_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122753,
    layer_pattern=(LayerSpec(kind="attn"),),
    attn=FULL_CAUSAL, tie_embeddings=True,
    dtype=jnp.bfloat16, remat="full", scan_layers=True, max_seq=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=72, num_layers=2, num_heads=4, num_kv_heads=4, head_dim=18,
    d_ff=144, vocab_size=512, dtype=jnp.float32, scan_layers=False,
    remat="none", loss_chunk=64, max_seq=256)
