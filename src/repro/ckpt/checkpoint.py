"""Sharded, async, atomic checkpointing (self-contained — no orbax).

Layout (per checkpoint step):
    <dir>/step_000123.tmp/          — staging
        shard_<host>.npz            — this host's param/opt leaves (flat keys)
        index.json                  — tree structure, shapes, dtypes, step
    <dir>/step_000123/              — atomic rename on commit

Fault-tolerance properties:
  * atomic: a crash mid-write leaves only a .tmp dir, never a corrupt ckpt;
  * async: `save_async` snapshots to host RAM synchronously (jax.device_get)
    then writes on a background thread — the train loop keeps stepping;
  * elastic: `restore` reshards to whatever mesh/sharding the *restoring*
    job uses — device counts may differ from the saving job (ft/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from pathlib import Path

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    root: dict = {}
    for key, v in flat.items():
        node = root
        parts = key.split(SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save(state, directory, step: int, host_id: int = 0, blocking: bool = True):
    """Snapshot + write.  Returns a `threading.Thread` if blocking=False."""
    directory = Path(directory)
    flat = _flatten(state)
    # synchronous snapshot (cheap: device->host copy), async disk write
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def write():
        # unique staging dir: concurrent/restarted writers of the same step
        # never collide; the atomic rename is the only commit point
        tmp = directory / f"step_{step:09d}.tmp.{uuid.uuid4().hex[:8]}"
        final = directory / f"step_{step:09d}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"shard_{host_id}.npz", **arrays)
        index = {
            "step": step,
            "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                     for k, a in arrays.items()},
        }
        (tmp / "index.json").write_text(json.dumps(index))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def save_async(state, directory, step, host_id: int = 0):
    return save(state, directory, step, host_id, blocking=False)


def latest_step(directory):
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1].split(".")[0]) for p in directory.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and ".tmp" not in p.name]
    return max(steps) if steps else None


def restore(directory, step=None, shardings=None, host_id: int = 0):
    """Load a checkpoint; optionally place leaves with `shardings`
    (a parallel tree of NamedSharding) — this is the elastic reshard path."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:09d}"
    with np.load(d / f"shard_{host_id}.npz") as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        tree = _unflatten({
            k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
            for k, v in _flatten(tree).items()})
    return tree, step
