"""Learning-rate schedules (self-contained, optax-free).

Includes WSD (warmup–stable–decay) for the minicpm recipe [arXiv:2404.06395],
plus linear-decay (the paper's own MLM recipe, App. E.1) and cosine.
All schedules are jnp-traceable functions of the step counter.
"""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup):
    return jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup, 1))


def linear(peak_lr, warmup, total):
    """Paper App. E.1: warmup then linear decay to 0."""
    def fn(step):
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return peak_lr * linear_warmup(step, warmup) * (1.0 - frac)
    return fn


def cosine(peak_lr, warmup, total, floor=0.1):
    def fn(step):
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * linear_warmup(step, warmup) * (floor + (1 - floor) * cos)
    return fn


def wsd(peak_lr, warmup, stable, total, floor=0.01):
    """Warmup-Stable-Decay (minicpm): hold at peak, then fast decay tail."""
    def fn(step):
        wu = linear_warmup(step, warmup)
        decay_frac = jnp.clip((step - stable) / jnp.maximum(total - stable, 1), 0, 1)
        decay = floor + (1 - floor) * (1 - decay_frac)
        return peak_lr * wu * jnp.where(step < stable, 1.0, decay)
    return fn


def constant(lr):
    return lambda step: jnp.asarray(lr)


def by_name(name, peak_lr, warmup, total):
    if name == "wsd":
        return wsd(peak_lr, warmup, int(total * 0.9), total)
    if name == "linear":
        return linear(peak_lr, warmup, total)
    if name == "constant":
        return constant(peak_lr)
    return cosine(peak_lr, warmup, total)
