"""Optimizers (optax-like minimal API, self-contained).

  * adamw     — dense archs.  m, v in f32; optional master f32 params.
  * adafactor — factored second moment (Shazeer & Stern), bf16 momentum.
                Required for grok-314b / jamba-398b: full Adam state would
                not fit 16 GB/chip at 256 chips (DESIGN.md §5).

Optimizer state mirrors the param tree, so the ZeRO-1 sharding rules applied
to params apply to the state for free.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params, step) -> (params', state', metrics)
    state_spec: Callable[[Any], Any]   # param P-spec tree -> state P-spec tree


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), norm


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm=1.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = step + 1
        c1 = 1 - b1 ** t.astype(F32)
        c2 = 1 - b2 ** t.astype(F32)

        def upd(g, m, v, p):
            g = g.astype(F32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        metrics = {"lr": lr, "grad_norm": gnorm}
        return new_p, {"m": new_m, "v": new_v}, metrics

    def state_spec(pspec):
        from repro.models.params import map_leaves
        import dataclasses as dc
        f32tree = map_leaves(lambda p: dc.replace(p, dtype=F32, init="zeros"), pspec)
        return {"m": f32tree, "v": f32tree}

    return Optimizer(init, update, state_spec)


def adafactor(lr_fn, b2_decay=0.8, eps=1e-30, clip_threshold=1.0,
              momentum=0.9, weight_decay=0.0) -> Optimizer:
    """Factored Adafactor with bf16 momentum (memory: ~1.0x params extra
    instead of Adam's 2x f32)."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], F32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32),
                        "m": jnp.zeros(p.shape, jnp.bfloat16)}
            return {"v": jnp.zeros(p.shape, F32),
                    "m": jnp.zeros(p.shape, jnp.bfloat16)}
        return {"s": jax.tree.map(leaf, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = (step + 1).astype(F32)
        beta2 = 1.0 - t ** (-b2_decay)

        def upd(g, s, p):
            g = g.astype(F32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                # u = g / sqrt(vr_hat (outer) vc_hat)
                rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g * jax.lax.rsqrt(rfac[..., None] + eps) * \
                    jax.lax.rsqrt(vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            m = momentum * s["m"].astype(F32) + (1 - momentum) * u
            new_s["m"] = m.astype(jnp.bfloat16)
            u = m + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * u).astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["s"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_s = treedef.unflatten([o[1] for o in outs])
        metrics = {"lr": lr, "grad_norm": global_norm(grads)}
        return new_p, {"s": new_s}, metrics

    def state_spec(pspec):
        from repro.models.params import P, map_leaves
        def leaf(p):
            if _factored(p.shape):
                return {"vr": P(p.shape[:-1], p.axes[:-1], init="zeros", dtype=F32),
                        "vc": P(p.shape[:-2] + p.shape[-1:],
                                p.axes[:-2] + p.axes[-1:], init="zeros", dtype=F32),
                        "m": P(p.shape, p.axes, init="zeros", dtype=jnp.bfloat16)}
            return {"v": P(p.shape, p.axes, init="zeros", dtype=F32),
                    "m": P(p.shape, p.axes, init="zeros", dtype=jnp.bfloat16)}
        return {"s": map_leaves(leaf, pspec)}

    return Optimizer(init, update, state_spec)


def by_name(name, lr_fn, **kw):
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    return adamw(lr_fn, **kw)
