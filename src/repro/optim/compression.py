"""Cross-pod gradient compression: int8 quantization with error feedback.

At 512+ chips the pod axis crosses DCN (slow links); the per-step gradient
all-reduce over `pod` is the scaling bottleneck.  This implements the
classic error-feedback scheme (1-bit-Adam lineage, here 8-bit):

    e   <- residual carried in optimizer state (same tree as grads)
    g'  <- g + e
    s   <- max|g'| / 127          (scale agreed across pods via psum-max)
    q   <- round(clip(g'/s))  in int8     (clip BEFORE round: the rounded
                                           value must already be in int8
                                           range, not clamped after the
                                           fact where round(127.5) = 128
                                           would alias onto the clip rail)
    out <- psum_pod(q) * s / n_pods
    e'  <- g' - q*s               (local quantization error, fed back)

Implemented as ONE shard_map over the FULL flattened gradient tree so the
int8 psums are visible in the compiled HLO (the dry-run measures the 4x
cross-pod byte reduction vs bf16; we psum int32 to avoid accumulation
overflow, so on-wire is int32; the *useful* trick on real DCN is the
hierarchical one below).  The shard-mapped function is cached per
(mesh, tree structure, pspecs, axis) — rebuilding it per leaf per call,
as this module once did, retraced every leaf on every step.

`compressed_grad_sync` assumes grads are already summed within each pod
(pjit produces pod-replicated grads when params are pod-replicated), so the
only remaining sync is across pods.  A mesh without the pod axis is the
single-pod case: the sync is the identity (no quantization noise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.5 keeps it in the experimental namespace
    from jax.experimental.shard_map import shard_map

F32 = jnp.float32


def _sync_one(g, e, axis):
    g = g.astype(F32) + e
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.round(jnp.clip(g / scale, -127.0, 127.0)).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    out = total.astype(F32) * scale / n.astype(F32)
    err = g - q.astype(F32) * scale
    return out, err


def _sync_flat(flat_g, flat_e, axis):
    """Per-shard body over the whole flattened tree: one traced function,
    one executable — however many leaves the model has."""
    outs = [_sync_one(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return tuple(o[0] for o in outs), tuple(o[1] for o in outs)


# (mesh, treedef, pspecs, axis) -> the shard-mapped flat sync function.
# Mesh, treedefs, and PartitionSpecs all hash; a second call with the same
# gradient tree reuses the traced closure instead of re-wrapping shard_map.
_SYNC_CACHE: dict = {}


def sync_cache_size() -> int:
    """Number of cached shard-mapped sync closures (tests assert reuse)."""
    return len(_SYNC_CACHE)


def compressed_grad_sync(grads, err_state, mesh, grad_pspecs,
                         axis: str = "pod"):
    """grads/err_state: pytrees; grad_pspecs: PartitionSpec tree matching the
    in-pod sharding of grads (pod axis must NOT appear in them).

    Returns (synced_grads, new_err_state)."""
    if axis not in mesh.axis_names:
        return grads, err_state      # single-pod: nothing to compress

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    flat_ps = tuple(treedef.flatten_up_to(grad_pspecs))
    key = (mesh, treedef, flat_ps, axis)
    fn = _SYNC_CACHE.get(key)
    if fn is None:
        fn = shard_map(
            functools.partial(_sync_flat, axis=axis),
            mesh=mesh, in_specs=(flat_ps, flat_ps),
            out_specs=(flat_ps, flat_ps))
        _SYNC_CACHE[key] = fn
    outs, errs = fn(tuple(flat_g),
                    tuple(e.astype(F32) for e in flat_e))
    return treedef.unflatten(list(outs)), treedef.unflatten(list(errs))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
