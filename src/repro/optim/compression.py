"""Cross-pod gradient compression: int8 quantization with error feedback.

At 512+ chips the pod axis crosses DCN (slow links); the per-step gradient
all-reduce over `pod` is the scaling bottleneck.  This implements the
classic error-feedback scheme (1-bit-Adam lineage, here 8-bit):

    e   <- residual carried in optimizer state (same tree as grads)
    g'  <- g + e
    s   <- max|g'| / 127          (scale agreed across pods via psum-max)
    q   <- round(g'/s)  in int8
    out <- psum_pod(q) * s / n_pods
    e'  <- g' - q*s               (local quantization error, fed back)

Implemented with shard_map over the FULL mesh so the int8 psum is visible
in the compiled HLO (the dry-run measures the 4x cross-pod byte reduction
vs bf16; 2x vs f32 wire would be int8+int32-accum — we psum int32 to avoid
overflow, so on-wire is int32; the *useful* trick on real DCN is the
hierarchical one below).

`compressed_grad_sync` assumes grads are already summed within each pod
(pjit produces pod-replicated grads when params are pod-replicated), so the
only remaining sync is across pods.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _sync_one(g, e, axis):
    g = g.astype(F32) + e
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    out = total.astype(F32) * scale / n.astype(F32)
    err = g - q.astype(F32) * scale
    return out, err


def compressed_grad_sync(grads, err_state, mesh, grad_pspecs,
                         axis: str = "pod"):
    """grads/err_state: pytrees; grad_pspecs: PartitionSpec tree matching the
    in-pod sharding of grads (pod axis must NOT appear in them).

    Returns (synced_grads, new_err_state)."""
    if axis not in mesh.axis_names:
        return grads, err_state      # single-pod: nothing to compress

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    flat_ps = treedef.flatten_up_to(grad_pspecs)

    outs = []
    for g, e, ps in zip(flat_g, flat_e, flat_ps):
        fn = jax.shard_map(
            functools.partial(_sync_one, axis=axis),
            mesh=mesh, in_specs=(ps, ps), out_specs=(ps, ps))
        outs.append(fn(g, e.astype(F32)))
    synced = treedef.unflatten([o[0] for o in outs])
    new_err = treedef.unflatten([o[1] for o in outs])
    return synced, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
