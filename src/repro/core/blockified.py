"""Paper-faithful blockified BigBird attention (App. D) in pure XLA.

This is the implementation the paper ships: pack, per query block, the
(g + w + r) key blocks into a dense tensor K'' and run one batched matmul.

  * window  — w rolled copies of the key-block tensor (jnp.roll == two static
              slices + concat; no gather),
  * global  — a fixed slice of the first g blocks, broadcast over query blocks,
  * random  — the only gather, with *static* (compile-time) indices.

Global query rows (first g blocks) are recomputed densely and overwrite the
kernel rows, exactly as in the paper ("the first row-block ... is computed by
direct multiplication").

This file is the **paper-faithful baseline**; `repro.kernels.bigbird_attn` is
the beyond-paper fused Pallas kernel.  Both must match
`repro.core.ref_attention.bigbird_attention_reference`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.core.ref_attention import NEG_INF, masked_softmax_attention

__all__ = ["bigbird_attention_blockified"]


def _pack_slots(xb, pat: patterns.BlockPattern):
    """xb: (B, Hkv, nb, b, d) -> packed (B, Hkv, nb, L, b, d) via roll/slice/take."""
    cfg = pat.cfg
    if cfg.pattern != "bigbird":
        # non-default policies own their slot layout: pack with one static
        # (compile-time) index gather over the full (nb, L) slot map
        return jnp.take(xb, jnp.asarray(pat.key_blocks), axis=2)
    g, w, r = cfg.num_global_blocks, cfg.num_window_blocks, cfg.num_random_blocks
    nb = pat.num_blocks
    parts = []
    # global: fixed slice, broadcast over query blocks
    if g:
        gl = xb[:, :, :g]                                    # (B,Hkv,g,b,d)
        gl = jnp.broadcast_to(gl[:, :, None], xb.shape[:2] + (nb, g) + xb.shape[3:])
        parts.append(gl)
    # window: rolled copies (paper Fig. 5). roll(shift=-off) puts block j+off at j.
    if w:
        offs = patterns._window_offsets(cfg)
        rolled = [jnp.roll(xb, shift=-int(off), axis=2) for off in offs]
        parts.append(jnp.stack(rolled, axis=3))              # (B,Hkv,nb,w,b,d)
    # random: static-index gather
    if r:
        idx = jnp.asarray(pat.key_blocks[:, g + w:])         # (nb, r)
        parts.append(jnp.take(xb, idx, axis=2))              # (B,Hkv,nb,r,b,d)
    return jnp.concatenate(parts, axis=3)


def _slot_masks(pat: patterns.BlockPattern):
    """Returns (block_mask (nb, L*b) bool, diag_refine (b, L*b) bool)."""
    cfg = pat.cfg
    b = cfg.block_size
    block_mask = pat.token_level_slot_mask()                 # (nb, L*b)
    L = pat.slots
    diag = np.ones((b, L * b), dtype=bool)
    if cfg.causal:
        # the policy names the slot holding the query's own block
        dslot = patterns.diag_slot(cfg)
        diag[:, dslot * b:(dslot + 1) * b] = np.tril(np.ones((b, b), dtype=bool))
    return jnp.asarray(block_mask), jnp.asarray(diag)


def bigbird_attention_blockified(q, k, v, cfg: patterns.BigBirdConfig,
                                 layer: int = 0):
    """q: (B, Hq, S, d); k, v: (B, Hkv, S, d) -> (B, Hq, S, d).

    GQA kv heads are broadcast to Hq up front so the head dim shards cleanly
    under tensor parallelism (see chunked_full for rationale).
    """
    from repro.core.ref_attention import repeat_kv
    B, Hq, S, d = q.shape
    k = repeat_kv(k, Hq)
    v = repeat_kv(v, Hq)
    b = cfg.block_size
    pat = patterns.build_pattern(cfg, S, layer=layer)
    nb, L = pat.num_blocks, pat.slots
    g = cfg.num_global_blocks
    scale = 1.0 / np.sqrt(d)

    qb = q.reshape(B, Hq, nb, b, d)
    kb = k.reshape(B, Hq, nb, b, d)
    vb = v.reshape(B, Hq, nb, b, d)

    kk = _pack_slots(kb, pat).reshape(B, Hq, nb, L * b, d)   # K''
    vv = _pack_slots(vb, pat).reshape(B, Hq, nb, L * b, d)   # V''

    logits = jnp.einsum("bhnqd,bhnkd->bhnqk", qb, kk,
                        preferred_element_type=jnp.float32) * scale
    block_mask, diag = _slot_masks(pat)
    mask = block_mask[:, None, :] & diag[None, :, :]          # (nb, b, L*b)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs * mask[None, None]
    denom = jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhnqk,bhnkd->bhnqd",
                     (probs / denom).astype(q.dtype), vv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, Hq, S, d).astype(q.dtype)

    # ---- dense recompute of global query rows (first g blocks) -------------
    if g:
        ng = g * b
        qg = q[:, :, :ng]                                     # (B,Hq,ng,d)
        if cfg.causal:
            m = jnp.arange(ng)[:, None] >= jnp.arange(S)[None, :]
        else:
            m = jnp.ones((ng, S), dtype=bool)
        og = masked_softmax_attention(qg, k, v, m, scale=scale)
        out = out.at[:, :, :ng].set(og.astype(out.dtype))
    return out
