"""Pure-jnp dense-mask oracle for BigBird attention.

O(n^2) memory — used only by tests and tiny benchmarks.  This is the ground
truth: the blockified XLA path and the Pallas kernel must match it bitwise
(up to float tolerance) for every pattern.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import patterns

NEG_INF = -1e30


def masked_softmax_attention(q, k, v, mask, scale=None):
    """q (..., Sq, d), k/v (..., Sk, d), mask (Sq, Sk) or broadcastable."""
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    # rows with no visible key (can happen for padded blocks) -> zeros
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs * mask
    denom = jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("...qk,...kd->...qd", probs / denom, v)


def repeat_kv(k, num_q_heads):
    """GQA: broadcast kv heads (..., Hkv, S, d) -> (..., Hq, S, d)."""
    hkv = k.shape[-3]
    if hkv == num_q_heads:
        return k
    group = num_q_heads // hkv
    return jnp.repeat(k, group, axis=-3)


def bigbird_attention_reference(q, k, v, cfg: patterns.BigBirdConfig,
                                layer: int = 0):
    """Oracle BigBird attention.

    q: (B, Hq, S, d); k, v: (B, Hkv, S, d).  Pattern is shared across heads
    within a layer (paper: random blocks fixed per layer); GQA broadcast done
    densely here.
    """
    b_, hq, s, d = q.shape
    pat = patterns.build_pattern(cfg, s, layer=layer)
    mask = jnp.asarray(patterns.dense_mask(pat))
    k = repeat_kv(k, hq)
    v = repeat_kv(v, hq)
    return masked_softmax_attention(q, k, v, mask)


def full_attention_reference(q, k, v, causal: bool = False):
    """Dense O(S^2) attention oracle; q (B,Hq,S,d), k/v (B,Hkv,S,d)."""
    b_, hq, sq, d = q.shape
    sk = k.shape[2]
    k = repeat_kv(k, hq)
    v = repeat_kv(v, hq)
    if causal:
        assert sq == sk
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool))
    else:
        mask = jnp.ones((sq, sk), dtype=bool)
    return masked_softmax_attention(q, k, v, mask)


def sliding_window_reference(q, k, v, window: int, causal: bool = True):
    """Token-level sliding window (SWA archs): |i-j| < window, j<=i if causal."""
    b_, hq, s, d = q.shape
    k = repeat_kv(k, hq)
    v = repeat_kv(v, hq)
    i = np.arange(s)[:, None]
    j = np.arange(s)[None, :]
    mask = np.abs(i - j) < window
    if causal:
        mask &= j <= i
    return masked_softmax_attention(q, k, v, jnp.asarray(mask))
