"""Unified attention dispatch — the paper's "generalized attention mechanism
described by a directed graph D" (Sec. 2), at block granularity.

Every attention-bearing layer in the model zoo calls `attention(...)` with an
`AttentionSpec`; the spec chooses the graph (full / sliding-window / BigBird)
and the implementation path:

  impl = "reference"   O(n^2) dense-mask oracle      (tests, tiny shapes)
         "blockified"  paper-faithful App-D XLA path (parity baseline)
         "pallas"      fused Pallas kernels          (production: fwd AND bwd
                       — custom_vjp flash-style backward, trains end-to-end;
                       see kernels/ops.py + DESIGN.md §Kernel autodiff)
         "chunked"     double-chunked XLA flash      (full attention only)

All impls are differentiable and must agree on gradients (tier-1:
tests/test_grads.py sweeps jax.grad parity across impls).

Sliding-window attention (SWA archs) is expressed as the BigBird *window
component alone* (r=0, g=0) at block granularity — the paper's own framing of
SWA as a subgraph.  Window width is rounded up to whole blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import blockified, chunked_full, patterns, ref_attention

__all__ = ["AttentionSpec", "attention"]


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Per-layer attention choice: kind, sparsity geometry, impl, policy.

    Frozen/hashable — model configs embed it and jit caches key on it.
    ``pattern`` names a registered PatternPolicy (core/patterns.py) and
    only applies to ``kind="bigbird"``; window layers always use the
    default layout (SWA is the window component alone).
    """

    kind: str = "full"                 # full | bigbird | window
    causal: bool = True
    # bigbird / window parameters (blocks)
    block_size: int = 64
    num_window_blocks: int = 3
    num_global_blocks: int = 2
    num_random_blocks: int = 3
    window_tokens: Optional[int] = None   # SWA: token window, rounded to blocks
    seed: int = 0
    impl: str = "blockified"           # reference | blockified | pallas | chunked
    pattern: str = "bigbird"           # PatternPolicy name (core/patterns.py)

    def bigbird_config(self, seq_len: int) -> patterns.BigBirdConfig:
        """Lower this spec to the BigBirdConfig the pattern builder keys on."""
        if self.kind == "window":
            # SWA is the window component alone — always the default layout
            assert self.window_tokens is not None
            wb = -(-self.window_tokens // self.block_size)     # ceil
            if not self.causal and wb % 2 == 0:
                wb += 1
            wb = min(wb, seq_len // self.block_size)
            return patterns.BigBirdConfig(
                block_size=self.block_size, num_window_blocks=wb,
                num_global_blocks=0, num_random_blocks=0,
                causal=self.causal, seed=self.seed)
        return patterns.BigBirdConfig(
            block_size=self.block_size,
            num_window_blocks=self.num_window_blocks,
            num_global_blocks=self.num_global_blocks,
            num_random_blocks=self.num_random_blocks,
            causal=self.causal, seed=self.seed, pattern=self.pattern)


def attention(q, k, v, spec: AttentionSpec, layer: int = 0):
    """q (B,Hq,S,d); k,v (B,Hkv,S,d) -> (B,Hq,S,d)."""
    S = q.shape[2]
    if spec.kind == "full":
        if spec.impl == "reference":
            return ref_attention.full_attention_reference(q, k, v, causal=spec.causal)
        return chunked_full.chunked_full_attention(q, k, v, causal=spec.causal)

    if spec.kind == "window" and spec.causal:
        from repro.dist.annotate import opt_level
        if spec.impl == "banded" or opt_level() >= 1:
            # beyond-paper: banded window attention (see core/banded.py).
            # Token-exact window (not block-rounded).
            from repro.core.banded import banded_window_attention
            W = spec.window_tokens
            if W is not None and W < S and S % min(512, S) == 0:
                return banded_window_attention(q, k, v, W)

    if spec.kind in ("bigbird", "window"):
        cfg = spec.bigbird_config(S)
        b = cfg.block_size
        pad = (-S) % b
        if pad and not spec.causal:
            # non-causal (encoder) callers must pad to block multiples at the
            # data layer (as the paper does); fall back to exact full attn.
            return chunked_full.chunked_full_attention(q, k, v, causal=False)
        if pad:
            # causal: pad the tail — padded keys are in the future of every
            # real query, so causality masks them; padded query rows are
            # sliced off.  Pattern rows are prefix-stable (see patterns.py),
            # so this matches bounded decode against a longer cache.
            zeros = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
            q, k, v = zeros(q), zeros(k), zeros(v)
        Sp = S + pad
        nb = Sp // b
        if not patterns.fits(cfg, nb):
            # pattern covers the whole (small) sequence: exact full attention
            return chunked_full.chunked_full_attention(
                q[:, :, :S], k[:, :, :S], v[:, :, :S], causal=spec.causal)
        if spec.impl == "reference":
            out = ref_attention.bigbird_attention_reference(q, k, v, cfg,
                                                            layer=layer)
        elif spec.impl == "pallas":
            from repro.kernels import ops                  # lazy import
            out = ops.bigbird_attention_fused(q, k, v, cfg, layer=layer)
        else:
            out = blockified.bigbird_attention_blockified(q, k, v, cfg,
                                                          layer=layer)
        return out[:, :, :S]

    raise ValueError(f"unknown attention kind: {spec.kind}")
