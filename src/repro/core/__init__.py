"""Model-agnostic building blocks: attention dispatch, pattern policies
(``core.patterns``), and the reference / blockified / chunked attention
implementations the fused kernels are verified against."""
