"""Block-sparse attention pattern algebra for BigBird.

The paper (Sec. 2, App. D) defines attention as a directed graph D over token
positions; BigBird "blockifies" it: the sequence is split into ``nb = n / b``
blocks and the pattern is expressed block-to-block.  Three components:

  * window  — query block j attends key blocks j-(w-1)/2 .. j+(w-1)/2
              (circular, matching the paper's rolled key tensor, Fig. 5);
              causal variant: key blocks j-w+1 .. j, clamped at 0.
  * global  — the first g blocks attend to everything and are attended by
              everything (ITC).  ETC is realised at the model level by
              prepending g*b learned tokens and running ITC on the result.
  * random  — each query block attends to r random key blocks, sampled once
              per (layer, head) with a fixed seed, avoiding window/global/self
              so no key block is duplicated inside the packed tensor.

Everything here is **static** (numpy, host-side): patterns are compile-time
constants, which is what makes the TPU kernel gather-free.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = [
    "BigBirdConfig",
    "BlockPattern",
    "build_pattern",
    "dense_mask",
    "transposed_pattern",
]


@dataclasses.dataclass(frozen=True)
class BigBirdConfig:
    """Static description of a BigBird attention pattern.

    Counts are in *blocks*, following App. D (paper base config:
    block 64, g = 2 blocks, w = 3 blocks, r = 3 blocks).
    """

    block_size: int = 64
    num_window_blocks: int = 3      # total window width in blocks (odd if not causal)
    num_global_blocks: int = 2      # ITC: first g blocks are global
    num_random_blocks: int = 3
    causal: bool = False
    seed: int = 0

    def __post_init__(self):
        if not self.causal and self.num_window_blocks % 2 == 0:
            raise ValueError("non-causal window must be odd (w/2 each side)")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")

    def validate(self, seq_len: int) -> None:
        if seq_len % self.block_size != 0:
            raise ValueError(
                f"seq_len {seq_len} not divisible by block {self.block_size}")
        nb = seq_len // self.block_size
        if self.num_global_blocks + self.num_window_blocks + self.num_random_blocks > nb:
            raise ValueError(
                f"pattern ({self.num_global_blocks}+{self.num_window_blocks}+"
                f"{self.num_random_blocks} blocks) larger than sequence ({nb} blocks); "
                "use full attention instead")


@dataclasses.dataclass(frozen=True)
class BlockPattern:
    """Materialised pattern for one (seq_len, config) pair.

    ``key_blocks[j, t]``  : index of the t-th key block for query block j.
    ``key_mask[j, t]``    : False where the slot is a duplicate / out of range
                            (masked out of the softmax).
    Slot layout along t: [g globals | w window | r random].
    Global *query* rows (j < g) additionally attend to every block; they are
    recomputed densely by the caller (paper: "the first row-block ... computed
    by direct multiplication").
    """

    cfg: BigBirdConfig
    seq_len: int
    num_blocks: int
    key_blocks: np.ndarray     # (nb, L) int32
    key_mask: np.ndarray       # (nb, L) bool

    @property
    def slots(self) -> int:
        return self.key_blocks.shape[1]

    def token_level_slot_mask(self) -> np.ndarray:
        """(nb, L*b) mask expanded to key positions inside each slot."""
        b = self.cfg.block_size
        return np.repeat(self.key_mask, b, axis=1)


def _window_offsets(cfg: BigBirdConfig) -> np.ndarray:
    w = cfg.num_window_blocks
    if cfg.causal:
        return np.arange(-(w - 1), 1)          # j-w+1 .. j
    half = w // 2
    return np.arange(-half, half + 1)          # j-w/2 .. j+w/2


@functools.lru_cache(maxsize=256)
def build_pattern(cfg: BigBirdConfig, seq_len: int,
                  layer: int = 0, head: int = 0) -> BlockPattern:
    """Build the static block pattern (cached: it is pure and reused often)."""
    cfg.validate(seq_len)
    b = cfg.block_size
    nb = seq_len // b
    g, w, r = cfg.num_global_blocks, cfg.num_window_blocks, cfg.num_random_blocks
    offs = _window_offsets(cfg)

    key_blocks = np.zeros((nb, g + w + r), dtype=np.int32)
    key_mask = np.zeros((nb, g + w + r), dtype=bool)

    # --- global slots -------------------------------------------------------
    key_blocks[:, :g] = np.arange(g)[None, :]
    key_mask[:, :g] = True

    # --- window slots -------------------------------------------------------
    j = np.arange(nb)[:, None]
    win = j + offs[None, :]                    # (nb, w)
    if cfg.causal:
        win_valid = win >= 0
        win_idx = np.clip(win, 0, nb - 1)
    else:
        win_valid = np.ones_like(win, dtype=bool)
        win_idx = win % nb                     # circular roll (paper Fig. 5)
    # dedup: window slot that lands on a global block is masked (global slot wins)
    win_valid &= win_idx >= g
    key_blocks[:, g:g + w] = win_idx
    key_mask[:, g:g + w] = win_valid

    # --- random slots -------------------------------------------------------
    # Seeded PER ROW (not per total length): causal patterns are then
    # *prefix-stable* — build_pattern(cfg, S1) rows agree with
    # build_pattern(cfg, S2) rows for every shared block.  This is what makes
    # prefill (prompt length) and bounded decode (cache length) attend the
    # same random graph.
    if r > 0:
        for jj in range(nb):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, layer, head, jj]))
            forbidden = set(range(g)) | {int(x) for x in win_idx[jj]} | {jj}
            hi = jj if cfg.causal else nb          # sample in [g, hi)
            n_free = max(hi - g - sum(1 for f in forbidden if g <= f < hi), 0)
            take = min(r, n_free)
            if take == 0:
                continue
            if hi - g <= 4 * (r + len(forbidden)):
                # small range: explicit candidate list
                cand = np.array([c for c in range(g, hi) if c not in forbidden])
                pick = rng.choice(cand, size=take, replace=False)
            else:
                # large range: rejection sampling, O(r) expected
                picks: list = []
                seen = set(forbidden)
                while len(picks) < take:
                    for c in rng.integers(g, hi, size=2 * take):
                        ci = int(c)
                        if ci not in seen:
                            seen.add(ci)
                            picks.append(ci)
                            if len(picks) == take:
                                break
                pick = np.array(picks)
            key_blocks[jj, g + w:g + w + take] = pick
            key_mask[jj, g + w:g + w + take] = True
    return BlockPattern(cfg=cfg, seq_len=seq_len, num_blocks=nb,
                        key_blocks=key_blocks, key_mask=key_mask)


@functools.lru_cache(maxsize=256)
def transposed_pattern(cfg: BigBirdConfig, seq_len: int,
                       layer: int = 0, head: int = 0):
    """Transposed slot map for the backward pass: queries *per key block*.

    Only the window/random slots (t >= g) of non-global query rows (j >= g)
    are transposed: the global slots (key blocks < g, referenced by every
    query row) have dense in-degree nb and get their own reduction kernel,
    and the global *query* rows (j < g) are recomputed densely — their
    sparse-kernel gradient is identically zero, so their edges would only
    pad the map.  Keeping both out bounds the padded width U by the max
    window+random in-degree: exactly O(w + r) for non-causal patterns;
    causal random picks concentrate on low-index key blocks, so U grows
    ~ w + r·log(nb) there (dead cells are masked, total padded work
    O(S log S) worst-case — still far below the O(S^2) of a dense map).

    Returns ``(tq, tmask)``:
      tq    (nb, U) int32 — query block indices attending key block i,
      tmask (nb, U) bool  — False on padding entries.
    U is the max in-degree over key blocks (>= 1 so kernel shapes are valid).
    """
    pat = build_pattern(cfg, seq_len, layer=layer, head=head)
    g = cfg.num_global_blocks
    nb = pat.num_blocks
    rows: list = [[] for _ in range(nb)]
    for j in range(g, nb):
        for t in range(g, pat.slots):
            if pat.key_mask[j, t]:
                rows[int(pat.key_blocks[j, t])].append(j)
    U = max(1, max((len(r) for r in rows), default=0))
    tq = np.zeros((nb, U), dtype=np.int32)
    tmask = np.zeros((nb, U), dtype=bool)
    for i, r in enumerate(rows):
        tq[i, :len(r)] = r
        tmask[i, :len(r)] = True
    return tq, tmask


def dense_mask(pat: BlockPattern) -> np.ndarray:
    """(n, n) boolean adjacency A[i, j'] — the oracle the kernels must match.

    Includes the global-rows rule (query rows in global blocks attend to all)
    and, if causal, the intersection with the causal mask.
    """
    cfg, b, nb, n = pat.cfg, pat.cfg.block_size, pat.num_blocks, pat.seq_len
    g = cfg.num_global_blocks
    A = np.zeros((nb, nb), dtype=bool)
    for j in range(nb):
        A[j, pat.key_blocks[j][pat.key_mask[j]]] = True
    A[:g, :] = True                      # global rows attend everywhere
    A[:, :g] = True                      # everyone attends to global blocks
    M = np.kron(A, np.ones((b, b), dtype=bool))
    if cfg.causal:
        M &= np.tril(np.ones((n, n), dtype=bool))
    return M
