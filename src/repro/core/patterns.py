"""Block-sparse attention patterns for BigBird, as pluggable policies.

The paper (Sec. 2, App. D) defines attention as a directed graph D over token
positions; BigBird "blockifies" it: the sequence is split into ``nb = n / b``
blocks and the pattern is expressed block-to-block.  The default policy is the
paper's three components:

  * window  — query block j attends key blocks j-(w-1)/2 .. j+(w-1)/2
              (circular, matching the paper's rolled key tensor, Fig. 5);
              causal variant: key blocks j-w+1 .. j, clamped at 0.
  * global  — the first g blocks attend to everything and are attended by
              everything (ITC).  ETC is realised at the model level by
              prepending g*b learned tokens and running ITC on the result.
  * random  — each query block attends to r random key blocks, sampled once
              per (layer, head) with a fixed seed, avoiding window/global/self
              so no key block is duplicated inside the packed tensor.

The *layout* is owned by a :class:`PatternPolicy` selected via
``BigBirdConfig.pattern``.  Registered policies (see DESIGN.md §Pattern
policies for the full contract):

  * ``"bigbird"``    — the paper's window+global+random layout (default).
  * ``"importance"`` — Smart Bird-style scored selection: the r random slots
                       are replaced by the top-r blocks under a cheap
                       deterministic importance proxy (dyadic-distance
                       scoring).  Frozen-selection mode: the chosen pattern
                       is static, so it trains straight through the
                       ``custom_vjp`` Pallas kernels unchanged.
  * ``"littlebird"`` — LittleBird-style layout: the random budget is folded
                       into a wider sliding window (w+r blocks) next to the
                       packed global blocks; same slot count as the default,
                       so wall-clock per step is matched.

Every policy emits the same artifacts the rest of the stack consumes — a
:class:`BlockPattern` (forward slot map), a transposed map for the dK/dV
backward kernels, and causal rows that are *prefix-stable* under growing
sequence length (required by chunked prefill and paged decode).  Everything
here is **static** (numpy, host-side): patterns are compile-time constants,
which is what makes the TPU kernel gather-free.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = [
    "BigBirdConfig",
    "BlockPattern",
    "PatternPolicy",
    "build_pattern",
    "dense_mask",
    "diag_slot",
    "fits",
    "get_policy",
    "min_blocks",
    "register_policy",
    "registered_policies",
    "transposed_pattern",
]


@dataclasses.dataclass(frozen=True)
class BigBirdConfig:
    """Static description of a block-sparse attention pattern.

    Counts are in *blocks*, following App. D (paper base config:
    block 64, g = 2 blocks, w = 3 blocks, r = 3 blocks).  ``pattern`` names
    the registered :class:`PatternPolicy` that turns these counts into a
    slot layout; the default ``"bigbird"`` is the paper's layout.  Instances
    are frozen and hashable — they key the ``build_pattern`` cache, ride
    inside ``jax.custom_vjp`` nondiff args, and are part of the serving
    engine's graph keys, so two configs that compare equal must always
    produce bit-identical patterns.
    """

    block_size: int = 64
    num_window_blocks: int = 3      # total window width in blocks (odd if not causal)
    num_global_blocks: int = 2      # ITC: first g blocks are global
    num_random_blocks: int = 3
    causal: bool = False
    seed: int = 0
    pattern: str = "bigbird"        # registered PatternPolicy name

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        get_policy(self.pattern).check(self)

    def validate(self, seq_len: int) -> None:
        """Raise ValueError unless the pattern fits a ``seq_len`` sequence."""
        if seq_len % self.block_size != 0:
            raise ValueError(
                f"seq_len {seq_len} not divisible by block {self.block_size}")
        nb = seq_len // self.block_size
        need = get_policy(self.pattern).min_blocks(self)
        if need > nb:
            raise ValueError(
                f"pattern {self.pattern!r} needs {need} blocks, sequence has "
                f"{nb}; use full attention instead")


@dataclasses.dataclass(frozen=True)
class BlockPattern:
    """Materialised pattern for one (seq_len, config) pair.

    ``key_blocks[j, t]``  : index of the t-th key block for query block j.
    ``key_mask[j, t]``    : False where the slot is a duplicate / out of range
                            (masked out of the softmax).
    Slot layout along t is policy-owned (default: [g globals | w window |
    r random]); consumers must treat it as opaque except for the contract
    exposed through :func:`diag_slot`.
    Global *query* rows (j < g) additionally attend to every block; they are
    recomputed densely by the caller (paper: "the first row-block ... computed
    by direct multiplication").
    """

    cfg: BigBirdConfig
    seq_len: int
    num_blocks: int
    key_blocks: np.ndarray     # (nb, L) int32
    key_mask: np.ndarray       # (nb, L) bool

    @property
    def slots(self) -> int:
        """Number of key-block slots L per query block."""
        return self.key_blocks.shape[1]

    def token_level_slot_mask(self) -> np.ndarray:
        """(nb, L*b) mask expanded to key positions inside each slot."""
        b = self.cfg.block_size
        return np.repeat(self.key_mask, b, axis=1)


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------


class PatternPolicy:
    """A block-sparse layout family, selected by ``BigBirdConfig.pattern``.

    Subclasses own the slot layout.  The contract every policy must satisfy
    (DESIGN.md §Pattern policies; property-tested in tests/test_patterns.py):

      * ``build`` returns a :class:`BlockPattern` whose masked slots, plus
        the dense global query/key rows, equal the policy's intended
        token-level adjacency (:func:`dense_mask` is derived from it).
      * Causal rows must be *prefix-stable*: row j of ``build(cfg, S1)``
        equals row j of ``build(cfg, S2)`` for every j both contain.  Paged
        decode and chunked prefill rebuild the pattern at growing cache
        lengths and assume earlier rows never change.
      * The only slot that may reference the query's own block is the one
        named by ``diag_slot`` (causal kernels apply the triangular mask
        there and nowhere else).
      * ``build`` must be a pure function of ``(cfg, seq_len, layer, head)``
        — results are cached and shared across the serving engine's graphs.
    """

    name = "?"

    def check(self, cfg: BigBirdConfig) -> None:
        """Reject configs the policy cannot realise (called from __post_init__)."""

    def min_blocks(self, cfg: BigBirdConfig) -> int:
        """Smallest block count the pattern fits; fewer -> full attention."""
        return (cfg.num_global_blocks + cfg.num_window_blocks
                + cfg.num_random_blocks)

    def diag_slot(self, cfg: BigBirdConfig) -> int:
        """Slot index holding the query's own block for causal patterns.

        Causal kernels refine exactly this slot with the intra-block
        triangular mask; -1 means no slot needs refinement (non-causal).
        """
        return -1

    def build(self, cfg: BigBirdConfig, seq_len: int,
              layer: int, head: int) -> BlockPattern:
        """Materialise the slot map; called via the cached :func:`build_pattern`."""
        raise NotImplementedError


_POLICIES: dict = {}


def register_policy(policy: PatternPolicy) -> PatternPolicy:
    """Register ``policy`` under ``policy.name`` (last registration wins)."""
    _POLICIES[policy.name] = policy
    return policy


def get_policy(name: str) -> PatternPolicy:
    """Look up a registered policy; raises ValueError with the known names."""
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown pattern policy {name!r}; registered: "
            f"{sorted(_POLICIES)}") from None


def registered_policies() -> tuple:
    """Names of all registered policies, sorted."""
    return tuple(sorted(_POLICIES))


def diag_slot(cfg: BigBirdConfig) -> int:
    """Policy-dispatched :meth:`PatternPolicy.diag_slot` for ``cfg``."""
    return get_policy(cfg.pattern).diag_slot(cfg)


def min_blocks(cfg: BigBirdConfig) -> int:
    """Policy-dispatched :meth:`PatternPolicy.min_blocks` for ``cfg``."""
    return get_policy(cfg.pattern).min_blocks(cfg)


def fits(cfg: BigBirdConfig, num_blocks: int) -> bool:
    """True if the pattern fits a ``num_blocks``-block sequence.

    Callers (attention dispatch, bounded decode, engine graph keys) fall
    back to exact full attention when this is False.
    """
    return num_blocks >= 0 and min_blocks(cfg) <= num_blocks


# ---------------------------------------------------------------------------
# shared layout helpers
# ---------------------------------------------------------------------------


def _window_offsets(cfg: BigBirdConfig) -> np.ndarray:
    """Window block offsets for the default layout (causal: trailing w)."""
    w = cfg.num_window_blocks
    if cfg.causal:
        return np.arange(-(w - 1), 1)          # j-w+1 .. j
    half = w // 2
    return np.arange(-half, half + 1)          # j-w/2 .. j+w/2


def _global_window_slots(cfg: BigBirdConfig, nb: int, offs: np.ndarray,
                         extra: int):
    """Fill the [g globals | window | extra] layout shared by the policies.

    Returns ``(key_blocks, key_mask, win_idx)`` with the trailing ``extra``
    slots zeroed/masked for the caller to fill.  ``win_idx`` is the (nb, w)
    window map after clipping/wrapping, needed to avoid duplicates.
    """
    g = cfg.num_global_blocks
    w = len(offs)
    key_blocks = np.zeros((nb, g + w + extra), dtype=np.int32)
    key_mask = np.zeros((nb, g + w + extra), dtype=bool)

    # --- global slots -------------------------------------------------------
    key_blocks[:, :g] = np.arange(g)[None, :]
    key_mask[:, :g] = True

    # --- window slots -------------------------------------------------------
    j = np.arange(nb)[:, None]
    win = j + offs[None, :]                    # (nb, w)
    if cfg.causal:
        win_valid = win >= 0
        win_idx = np.clip(win, 0, nb - 1)
    else:
        win_valid = np.ones_like(win, dtype=bool)
        win_idx = win % nb                     # circular roll (paper Fig. 5)
    # dedup: window slot that lands on a global block is masked (global slot wins)
    win_valid &= win_idx >= g
    key_blocks[:, g:g + w] = win_idx
    key_mask[:, g:g + w] = win_valid
    return key_blocks, key_mask, win_idx


class BigBirdPolicy(PatternPolicy):
    """The paper's layout: [g globals | w window | r random] (default)."""

    name = "bigbird"

    def check(self, cfg: BigBirdConfig) -> None:
        """Non-causal windows must be odd so w/2 sits on each side."""
        if not cfg.causal and cfg.num_window_blocks % 2 == 0:
            raise ValueError("non-causal window must be odd (w/2 each side)")

    def diag_slot(self, cfg: BigBirdConfig) -> int:
        """Causal: the offset-0 window slot is the last window slot."""
        return (cfg.num_global_blocks + cfg.num_window_blocks - 1
                if cfg.causal else -1)

    def build(self, cfg: BigBirdConfig, seq_len: int,
              layer: int, head: int) -> BlockPattern:
        """Globals + window + per-row seeded random slots (App. D)."""
        b = cfg.block_size
        nb = seq_len // b
        g, w, r = (cfg.num_global_blocks, cfg.num_window_blocks,
                   cfg.num_random_blocks)
        key_blocks, key_mask, win_idx = _global_window_slots(
            cfg, nb, _window_offsets(cfg), r)

        # --- random slots ---------------------------------------------------
        # Seeded PER ROW (not per total length): causal patterns are then
        # *prefix-stable* — build_pattern(cfg, S1) rows agree with
        # build_pattern(cfg, S2) rows for every shared block.  This is what
        # makes prefill (prompt length) and bounded decode (cache length)
        # attend the same random graph.
        if r > 0:
            for jj in range(nb):
                rng = np.random.default_rng(
                    np.random.SeedSequence([cfg.seed, layer, head, jj]))
                forbidden = set(range(g)) | {int(x) for x in win_idx[jj]} | {jj}
                hi = jj if cfg.causal else nb          # sample in [g, hi)
                n_free = max(hi - g - sum(1 for f in forbidden if g <= f < hi), 0)
                take = min(r, n_free)
                if take == 0:
                    continue
                if hi - g <= 4 * (r + len(forbidden)):
                    # small range: explicit candidate list
                    cand = np.array([c for c in range(g, hi) if c not in forbidden])
                    pick = rng.choice(cand, size=take, replace=False)
                else:
                    # large range: rejection sampling, O(r) expected
                    picks: list = []
                    seen = set(forbidden)
                    while len(picks) < take:
                        for c in rng.integers(g, hi, size=2 * take):
                            ci = int(c)
                            if ci not in seen:
                                seen.add(ci)
                                picks.append(ci)
                                if len(picks) == take:
                                    break
                    pick = np.array(picks)
                key_blocks[jj, g + w:g + w + take] = pick
                key_mask[jj, g + w:g + w + take] = True
        return BlockPattern(cfg=cfg, seq_len=seq_len, num_blocks=nb,
                            key_blocks=key_blocks, key_mask=key_mask)


class ImportancePolicy(BigBirdPolicy):
    """Smart Bird-style scored selection in place of the random slots.

    Globals and window are identical to the default layout; the r random
    slots are instead the top-r candidate blocks under a cheap importance
    proxy: candidates at dyadic (power-of-two) block distances score
    highest, larger reach preferred, ties broken toward lower block index.
    The selection is *frozen* (a pure function of the query-block index):
    this is the straight-through mode — gradients flow through the selected
    values exactly as for any static pattern, so the ``custom_vjp`` Pallas
    kernels train it unchanged.  Causal rows depend only on blocks strictly
    left of the query, so they are prefix-stable by construction.
    """

    name = "importance"

    def build(self, cfg: BigBirdConfig, seq_len: int,
              layer: int, head: int) -> BlockPattern:
        """Globals + window + top-r dyadic-importance slots."""
        b = cfg.block_size
        nb = seq_len // b
        g, w, r = (cfg.num_global_blocks, cfg.num_window_blocks,
                   cfg.num_random_blocks)
        key_blocks, key_mask, win_idx = _global_window_slots(
            cfg, nb, _window_offsets(cfg), r)

        if r > 0:
            for jj in range(nb):
                forbidden = set(range(g)) | {int(x) for x in win_idx[jj]} | {jj}
                hi = jj if cfg.causal else nb          # candidates in [g, hi)
                cand = np.array(
                    [c for c in range(g, hi) if c not in forbidden],
                    dtype=np.int64)
                if cand.size == 0:
                    continue
                dist = np.abs(jj - cand).astype(np.float64)
                ld = np.log2(dist)
                # dyadic alignment dominates (0 at exact powers of two),
                # then larger reach; stable argsort makes ties deterministic
                score = -np.abs(ld - np.round(ld)) * 1e3 + ld
                order = np.argsort(-score, kind="stable")
                pick = cand[order[:r]]
                take = len(pick)
                key_blocks[jj, g + w:g + w + take] = pick
                key_mask[jj, g + w:g + w + take] = True
        return BlockPattern(cfg=cfg, seq_len=seq_len, num_blocks=nb,
                            key_blocks=key_blocks, key_mask=key_mask)


class LittleBirdPolicy(PatternPolicy):
    """LittleBird-style layout: packed globals + a wider sliding window.

    The random budget is folded into the window — the layout is
    [g globals | (w + r) window], the same total slot count as the default,
    so wall-clock per step is matched.  The packed-global projection of
    LittleBird is realised by the existing ITC global blocks (as with ETC,
    packing extra learned globals is a model-level concern).  Non-causal
    even-width windows split asymmetrically (one extra block to the left),
    so any (w, r) budget is accepted.
    """

    name = "littlebird"

    def _offsets(self, cfg: BigBirdConfig) -> np.ndarray:
        we = cfg.num_window_blocks + cfg.num_random_blocks
        if cfg.causal:
            return np.arange(-(we - 1), 1)     # j-we+1 .. j
        left = we // 2
        return np.arange(-left, we - left)     # len we, contains 0

    def diag_slot(self, cfg: BigBirdConfig) -> int:
        """Causal: offset-0 slot is the last slot of the widened window."""
        return (cfg.num_global_blocks + cfg.num_window_blocks
                + cfg.num_random_blocks - 1 if cfg.causal else -1)

    def build(self, cfg: BigBirdConfig, seq_len: int,
              layer: int, head: int) -> BlockPattern:
        """Globals + widened window; no data-dependent or random slots."""
        nb = seq_len // cfg.block_size
        key_blocks, key_mask, _ = _global_window_slots(
            cfg, nb, self._offsets(cfg), 0)
        return BlockPattern(cfg=cfg, seq_len=seq_len, num_blocks=nb,
                            key_blocks=key_blocks, key_mask=key_mask)


register_policy(BigBirdPolicy())
register_policy(ImportancePolicy())
register_policy(LittleBirdPolicy())


# ---------------------------------------------------------------------------
# cached builders (the public entry points)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def build_pattern(cfg: BigBirdConfig, seq_len: int,
                  layer: int = 0, head: int = 0) -> BlockPattern:
    """Build the static block pattern (cached: it is pure and reused often).

    Dispatches to the policy named by ``cfg.pattern``.  Returns a
    :class:`BlockPattern` with ``key_blocks`` (nb, L) int32 and ``key_mask``
    (nb, L) bool, where ``nb = seq_len // cfg.block_size``.
    """
    cfg.validate(seq_len)
    return get_policy(cfg.pattern).build(cfg, seq_len, layer, head)


@functools.lru_cache(maxsize=256)
def transposed_pattern(cfg: BigBirdConfig, seq_len: int,
                       layer: int = 0, head: int = 0):
    """Transposed slot map for the backward pass: queries *per key block*.

    Only the non-global slots (t >= g) of non-global query rows (j >= g)
    are transposed: the global slots (key blocks < g, referenced by every
    query row) have dense in-degree nb and get their own reduction kernel,
    and the global *query* rows (j < g) are recomputed densely — their
    sparse-kernel gradient is identically zero, so their edges would only
    pad the map.  Keeping both out bounds the padded width U by the max
    window+random in-degree: exactly O(w + r) for non-causal patterns;
    causal random/importance picks concentrate on low-index key blocks, so
    U grows ~ w + r·log(nb) there (dead cells are masked, total padded work
    O(S log S) worst-case — still far below the O(S^2) of a dense map).

    Policy-generic: derived from ``build_pattern``'s output, so it is the
    exact inverse of the forward map for every registered policy
    (property-tested in tests/test_patterns.py).

    Returns ``(tq, tmask)``:
      tq    (nb, U) int32 — query block indices attending key block i,
      tmask (nb, U) bool  — False on padding entries.
    U is the max in-degree over key blocks (>= 1 so kernel shapes are valid).
    """
    pat = build_pattern(cfg, seq_len, layer=layer, head=head)
    g = cfg.num_global_blocks
    nb = pat.num_blocks
    rows: list = [[] for _ in range(nb)]
    for j in range(g, nb):
        for t in range(g, pat.slots):
            if pat.key_mask[j, t]:
                rows[int(pat.key_blocks[j, t])].append(j)
    U = max(1, max((len(r) for r in rows), default=0))
    tq = np.zeros((nb, U), dtype=np.int32)
    tmask = np.zeros((nb, U), dtype=bool)
    for i, r in enumerate(rows):
        tq[i, :len(r)] = r
        tmask[i, :len(r)] = True
    return tq, tmask


def dense_mask(pat: BlockPattern) -> np.ndarray:
    """(n, n) boolean adjacency A[i, j'] — the oracle the kernels must match.

    Includes the global-rows rule (query rows in global blocks attend to all)
    and, if causal, the intersection with the causal mask.  Policy-generic:
    any :class:`BlockPattern` expands the same way.
    """
    cfg, b, nb, n = pat.cfg, pat.cfg.block_size, pat.num_blocks, pat.seq_len
    g = cfg.num_global_blocks
    A = np.zeros((nb, nb), dtype=bool)
    for j in range(nb):
        A[j, pat.key_blocks[j][pat.key_mask[j]]] = True
    A[:g, :] = True                      # global rows attend everywhere
    A[:, :g] = True                      # everyone attends to global blocks
    M = np.kron(A, np.ones((b, b), dtype=bool))
    if cfg.causal:
        M &= np.tril(np.ones((n, n), dtype=bool))
    return M
