"""Banded sliding-window attention (beyond-paper optimization, §Perf).

The paper's blockified window (App. D) materializes w rolled copies of the
key tensor — fine for w=3 blocks, but SWA archs carry windows of 16+ blocks
(gemma3: 1024 tokens / 64 = 16), so K''/V'' duplicate the cache 16x.  This
implementation scans query chunks and dynamic-slices ONE contiguous key band
per chunk: each key is read ~(1 + W/q_chunk) times instead of w times, and
no packed tensor is materialized.

Exactly equivalent to the token-level sliding window mask
(qpos - kpos in [0, W)), causal.  Used when AttentionSpec.impl == "banded"
or opt_level >= 1 for kind == "window".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ref_attention import NEG_INF, repeat_kv

__all__ = ["banded_window_attention"]


def banded_window_attention(q, k, v, window: int, *, q_chunk: int = 512):
    """q (B,Hq,S,d); k,v (B,Hkv,S,d); causal window: qpos-kpos in [0, window)."""
    B, Hq, S, d = q.shape
    k = repeat_kv(k, Hq)
    v = repeat_kv(v, Hq)
    q_chunk = min(q_chunk, S)
    assert S % q_chunk == 0
    nq = S // q_chunk
    band = min(q_chunk + window, S)          # static band width
    scale = 1.0 / np.sqrt(d)

    qs = q.reshape(B, Hq, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)

    @jax.checkpoint
    def q_step(_, qi_qc):
        """One query chunk against its static-width key/value band."""
        qi, qc = qi_qc
        start = jnp.clip(qi * q_chunk + q_chunk - band, 0, S - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, kb,
                       preferred_element_type=jnp.float32) * scale
        qpos = qi * q_chunk + jnp.arange(q_chunk)
        kpos = start + jnp.arange(band)
        delta = qpos[:, None] - kpos[None, :]
        mask = (delta >= 0) & (delta < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(qc.dtype), vb,
                         preferred_element_type=jnp.float32)
        return None, (out / jnp.maximum(l, 1e-30)).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, S, d)
