"""Memory-efficient full attention in pure XLA (double-chunked online softmax).

Dense archs (yi, minicpm, internvl2, llama4, grok) need full attention at
train_4k / prefill_32k; materializing (B, H, S, S) scores would OOM a 16 GB
chip at 32k.  This computes the same result with O(S * chunk) live memory via
a scan over query chunks (rematted: jax.checkpoint, so backward recomputes
the inner scan instead of saving per-chunk probs/masks) with an inner scan
over key chunks carrying flash-style (m, l, acc) accumulators.

GQA note: kv heads are broadcast to the full Hq head dim *before* the scans.
Keeping a (Hkv, group) split would make both dims unshardable when
Hkv < model-axis (e.g. yi: kv=4 on model=16); broadcasting keeps the head
dim = Hq, which shards cleanly, at negligible local kv cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ref_attention import NEG_INF, repeat_kv

__all__ = ["chunked_full_attention"]


def chunked_full_attention(q, k, v, *, causal: bool = False,
                           q_chunk: int = 1024, k_chunk: int = 1024):
    """q (B,Hq,Sq,d); k,v (B,Hkv,Sk,d) -> (B,Hq,Sq,d).  Sq != Sk allowed
    (cross-attention); causal requires Sq == Sk."""
    B, Hq, Sq, d = q.shape
    k = repeat_kv(k, Hq)
    v = repeat_kv(v, Hq)
    Sk = k.shape[2]
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0
    if causal:
        assert Sq == Sk
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = 1.0 / np.sqrt(d)

    qs = q.reshape(B, Hq, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)
    ks = k.reshape(B, Hq, nk, k_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hq, nk, k_chunk, d).transpose(2, 0, 1, 3, 4)

    @jax.checkpoint
    def q_step(_, qi_qc):
        """Stream all key chunks past one query chunk (flash softmax)."""
        qi, qc = qi_qc                                    # qc: (B,Hq,qcnk,d)

        def k_step(carry, ki_kc):
            """Fold one key/value chunk into the running (m, l, acc)."""
            m, l, acc = carry
            ki, kc, vc = ki_kc
            s = jnp.einsum("bhqd,bhkd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * k_chunk + jnp.arange(k_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            # no post-exp re-mask needed: every query row sees >= 1 visible
            # key in its first k-chunk (causal: the diagonal; full: all), so
            # m_new > NEG_INF and exp underflows to exactly 0 on masked keys.
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(qc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hq, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((B, Hq, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # outs: (nq, B, Hq, qc, d) -> (B, Hq, Sq, d)
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Sq, d)
