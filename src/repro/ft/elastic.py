"""Elastic scaling + failure handling.

The recovery contract at 1000+ nodes:

  1. A node failure kills the SPMD step (collective timeout / coordinator
     eviction).  The launcher (launch/train.py) catches it, re-forms the
     device set, and calls `replan` here.
  2. `replan` rebuilds the mesh for the surviving device count (largest
     (data, model) factorization that keeps model parallelism intact),
     re-derives every PartitionSpec through dist.sharding (all rules are
     divisibility-checked, so a smaller mesh degrades to replication rather
     than failing), and reshards the restored checkpoint onto it.
  3. Data determinism: pipeline batches are pure functions of
     (seed, host_id, num_hosts, step), so re-assigned hosts resume exactly
     the right stream — no sample is lost or duplicated.

Straggler mitigation (`straggler.py`): deterministic per-step deadlines with
a skip-list — a host that misses the deadline k times is evicted and
treated as a failure (same replan path), which bounds tail latency instead
of letting one slow host gate every step.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class MeshPlan:
    shape: tuple
    axes: tuple

    def build(self):
        return jax.make_mesh(self.shape, self.axes)


def plan_mesh(n_devices: int, *, model_parallel: int = 16,
              pods: int = 1) -> MeshPlan:
    """Largest mesh for `n_devices`, preserving TP degree when possible.

    Drops to smaller model-parallel degrees (powers of two) when the device
    count is not divisible — elastic *downscale* after failures.
    """
    per_pod = n_devices // pods
    mp = model_parallel
    while mp > 1 and per_pod % mp != 0:
        mp //= 2
    data = per_pod // mp
    if pods > 1:
        return MeshPlan((pods, data, mp), ("pod", "data", "model"))
    return MeshPlan((data, mp), ("data", "model"))


def usable_device_count(n_devices: int, *, model_parallel: int = 16,
                        pods: int = 1) -> int:
    """Devices actually used after replanning (rest idle until repair)."""
    plan = plan_mesh(n_devices, model_parallel=model_parallel, pods=pods)
    return int(np.prod(plan.shape))


def reshard_state(state, cfg, opt, new_mesh):
    """Re-place a host-restored state tree onto a (possibly different) mesh."""
    from repro.launch import steps as S
    from jax.sharding import NamedSharding

    ps = S.state_pspec_tree(cfg, opt, new_mesh)

    def place(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    return jax.tree.map(place, state, ps,
                        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))
