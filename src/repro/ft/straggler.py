"""Straggler detection and mitigation (host-side policy).

Synchronous SPMD training is gated by the slowest participant.  At 1000+
nodes, persistent stragglers (thermal throttling, failing HBM, noisy
neighbors on DCN) dominate tail step time.  Policy implemented here:

  * per-step wall-clock EWMA with deviation tracking;
  * a host flagged when its step time exceeds mean + `k_sigma` * sigma for
    `patience` consecutive steps;
  * flagged hosts are *evicted* (returned by `to_evict`) and the launcher
    replans the mesh without them (ft/elastic.py) — trading a little
    capacity for bounded step time;
  * data ownership transfers deterministically (pipeline is a pure function
    of host_id/num_hosts/step), so eviction loses no samples.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class StragglerConfig:
    k_sigma: float = 3.0
    patience: int = 5
    ewma: float = 0.9
    min_steps: int = 10


class StragglerDetector:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.mean = defaultdict(float)
        self.var = defaultdict(float)
        self.strikes = defaultdict(int)
        self.steps = defaultdict(int)

    def observe(self, host_id: int, step_time: float):
        c = self.cfg
        m, v = self.mean[host_id], self.var[host_id]
        if self.steps[host_id] == 0:
            self.mean[host_id], self.var[host_id] = step_time, 0.0
        else:
            delta = step_time - m
            self.mean[host_id] = m + (1 - c.ewma) * delta
            self.var[host_id] = c.ewma * (v + (1 - c.ewma) * delta * delta)
        self.steps[host_id] += 1

    def is_straggling(self, host_id: int, step_time: float,
                      fleet_mean: float, fleet_sigma: float) -> bool:
        c = self.cfg
        if self.steps[host_id] < c.min_steps or fleet_sigma <= 0:
            return False
        if step_time > fleet_mean + c.k_sigma * fleet_sigma:
            self.strikes[host_id] += 1
        else:
            self.strikes[host_id] = 0
        return self.strikes[host_id] >= c.patience

    def fleet_stats(self, exclude=None):
        """Leave-one-out stats: a persistent straggler must not inflate the
        fleet sigma it is judged against."""
        ms = [m for h, m in self.mean.items() if h != exclude]
        if not ms:
            return 0.0, 0.0
        mean = sum(ms) / len(ms)
        var = sum((m - mean) ** 2 for m in ms) / max(len(ms) - 1, 1)
        return mean, max(var ** 0.5, 0.01 * mean)

    def to_evict(self, step_times: dict) -> list:
        out = []
        for h, t in step_times.items():
            self.observe(h, t)
        for h, t in step_times.items():
            mean, sigma = self.fleet_stats(exclude=h)
            if self.is_straggling(h, t, mean, sigma):
                out.append(h)
        return out
