"""Layer library for the model zoo.

Pure functions over explicit param pytrees (specs from models.params.P).
Matmuls run in the model dtype (bf16 by default) with f32 accumulation;
norms/softmax/router run in f32.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionSpec, attention
from repro.models.params import P

F32 = jnp.float32


# --------------------------------------------------------------------------
# norms / rope / embedding
# --------------------------------------------------------------------------

def rms_norm_spec(d):
    return {"scale": P((d,), ("embed",), init="ones")}


def rms_norm(p, x, eps=1e-5):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(F32)).astype(x.dtype)


def rope(x, positions, theta=1e4):
    """x (B, H, S, dh); positions (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freq           # (S, half) | (B,S,half)
    if ang.ndim == 2:
        ang = ang[None, None]                                # (1,1,S,half)
    else:
        ang = ang[:, None]                                   # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def embedding_spec(vocab, d):
    return {"table": P((vocab, d), ("vocab", "embed"), init="normal")}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


# --------------------------------------------------------------------------
# attention block
# --------------------------------------------------------------------------

def attn_block_spec(d, hq, hkv, dh):
    return {
        "norm": rms_norm_spec(d),
        "wq": P((d, hq * dh), ("embed", "heads"), init="scaled"),
        "wk": P((d, hkv * dh), ("embed", "kv_heads"), init="scaled"),
        "wv": P((d, hkv * dh), ("embed", "kv_heads"), init="scaled"),
        "wo": P((hq * dh, d), ("heads", "embed"), init="scaled"),
    }


def _project_qkv(p, x, hq, hkv, dh, positions, theta):
    from repro.dist.annotate import constrain
    B, S, d = x.shape
    q = (x @ p["wq"]).reshape(B, S, hq, dh).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, hkv, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, hkv, dh).transpose(0, 2, 1, 3)
    q = constrain(q, ("batch", "heads", None, None))
    k = constrain(k, ("batch", "kv_heads", None, None))
    v = constrain(v, ("batch", "kv_heads", None, None))
    if positions is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def attn_block(p, x, spec: AttentionSpec, hq, hkv, dh, *, positions=None,
               theta=1e4, layer=0, eps=1e-5, kv_override=None,
               return_kv=False):
    """Self-attention (or cross-attention via kv_override) block, pre-norm."""
    B, S, d = x.shape
    h = rms_norm(p["norm"], x, eps)
    if kv_override is not None:                     # cross-attn: kv from encoder
        q = (h @ p["wq"]).reshape(B, S, hq, dh).transpose(0, 2, 1, 3)
        k, v = kv_override
    else:
        q, k, v = _project_qkv(p, h, hq, hkv, dh, positions, theta)
    o = attention(q, k, v, spec, layer=layer)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, hq * dh)
    from repro.dist.annotate import constrain
    o = constrain(o, ("batch", None, "heads"))
    out = x + o @ p["wo"]
    out = constrain(out, ("batch", None, "embed"))
    if return_kv:
        return out, (k, v)
    return out


def cross_kv(p, enc_h, hkv, dh):
    """Precompute cross-attention K/V from encoder states (decode reuses)."""
    B, S, d = enc_h.shape
    k = (enc_h @ p["wk"]).reshape(B, S, hkv, dh).transpose(0, 2, 1, 3)
    v = (enc_h @ p["wv"]).reshape(B, S, hkv, dh).transpose(0, 2, 1, 3)
    return k, v


# --------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# --------------------------------------------------------------------------

def mlp_spec(d, ff):
    return {
        "norm": rms_norm_spec(d),
        "wi": P((d, 2 * ff), ("embed", "mlp"), init="scaled"),   # [gate|up]
        "wo": P((ff, d), ("mlp", "embed"), init="scaled"),
    }


def mlp_block(p, x, eps=1e-5):
    from repro.dist.annotate import constrain
    h = rms_norm(p["norm"], x, eps)
    gu = constrain(h @ p["wi"], ("batch", None, "mlp"))
    gate, up = jnp.split(gu, 2, axis=-1)
    out = x + (jax.nn.silu(gate) * up) @ p["wo"]
    return constrain(out, ("batch", None, "embed"))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25


def moe_spec(d, moe: MoEConfig):
    e, ff = moe.num_experts, moe.d_ff
    return {
        "norm": rms_norm_spec(d),
        "router": P((d, e), ("embed", None), init="small"),
        "wi": P((e, d, 2 * ff), ("experts", "embed", "mlp"), init="scaled"),
        "wo": P((e, ff, d), ("experts", "mlp", "embed"), init="scaled"),
    }


def moe_block(p, x, moe: MoEConfig, eps=1e-5):
    """Top-k routed MoE with static capacity (GShard-style, scatter dispatch).

    Returns (y, aux_loss).  Dropped tokens (over capacity) fall through via
    the residual connection.

    Beyond-paper optimization (opt_level >= 1, §Perf): *locally-sharded
    dispatch*.  The baseline computes slot positions with a global cumsum
    over all tokens, which forces GSPMD to all-gather the full (N, d) token
    buffer across the mesh before the expert matmuls (the dominant
    collective in the grok/jamba prefill cells).  With D data shards we
    instead give every shard its own capacity slice C/D and compute
    positions with a per-shard cumsum — no cross-shard data dependency, so
    tokens are dispatched into shard-local capacity and the all-gather
    disappears.  Same drop semantics per shard; capacity is unchanged in
    aggregate.
    """
    from repro.dist.annotate import data_shards, opt_level
    B, S, d = x.shape
    E, K = moe.num_experts, moe.top_k
    N = B * S
    D = data_shards() if opt_level() >= 1 else 1
    if N % D != 0:
        D = 1
    Nl = N // D
    Cl = max(int(np.ceil(Nl * K / E * moe.capacity_factor)), 1)
    C = Cl * D

    h = rms_norm(p["norm"], x, eps).reshape(N, d)
    logits = (h.astype(F32) @ p["router"].astype(F32))        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                       # (N, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), F32).at[topi.reshape(-1)].add(
        jnp.ones((N * K,), F32)) / (N * K)
    aux = E * jnp.sum(me * ce)

    from repro.dist.annotate import constrain
    if D > 1:
        # --- locally-sharded dispatch (opt_level >= 1) -------------------
        # batch-parallel scatter/gather via vmap over the shard dim: the
        # shard dim of operands, updates and indices all carry the same
        # "capacity" sharding, so GSPMD lowers them with NO collectives
        # (the baseline's partitioned scatter all-reduces the full buffer).
        oh = jax.nn.one_hot(topi.reshape(D, Nl * K), E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=1) - oh                      # (D, Nl*K, E)
        local = jnp.sum(pos * oh, axis=-1)                     # (D, Nl*K)
        keep = local < Cl
        slot = jnp.where(keep, local, 0)
        ti = topi.reshape(D, Nl * K)
        hx = jnp.repeat(h.reshape(D, Nl, d), K, axis=1)        # (D, Nl*K, d)
        upd = hx * keep[..., None].astype(h.dtype)

        buf = jax.vmap(
            lambda u, t, s: jnp.zeros((E, Cl, d), h.dtype).at[t, s].add(
                u, mode="drop"))(upd, ti, slot)                # (D, E, Cl, d)
        buf = constrain(buf, ("capacity", "experts", None, "embed"))
        gu = jnp.einsum("xecd,edf->xecf", buf, p["wi"])
        gu = constrain(gu, ("capacity", "experts", None, "mlp"))
        gate, up = jnp.split(gu, 2, axis=-1)
        act = jax.nn.silu(gate) * up
        out = jnp.einsum("xecf,efd->xecd", act, p["wo"])
        out = constrain(out, ("capacity", "experts", None, "embed"))
        y = jax.vmap(lambda o, t, s: o[t, s])(out, ti, slot)   # (D, Nl*K, d)
        y = y * keep[..., None].astype(out.dtype)
        y = y * topv.reshape(D, Nl * K, 1).astype(out.dtype)
        y = y.reshape(N, K, d).sum(axis=1)
        return x + y.reshape(B, S, d), aux

    # --- baseline global dispatch ---------------------------------------
    ti = topi.reshape(N * K)
    onehot = jax.nn.one_hot(ti, E, dtype=jnp.int32)            # (N*K, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)                # exclusive
    slot = jnp.sum(pos * onehot, axis=-1)                      # (N*K,)
    keep = slot < C
    slot = jnp.where(keep, slot, 0)

    hx = jnp.repeat(h, K, axis=0)                              # (N*K, d)
    buf = jnp.zeros((E, C, d), h.dtype).at[ti, slot].add(
        hx * keep[:, None].astype(h.dtype), mode="drop")
    buf = constrain(buf, ("experts", None, "embed"))
    gu = jnp.einsum("ecd,edf->ecf", buf, p["wi"])              # (E, C, 2ff)
    gu = constrain(gu, ("experts", None, "mlp"))
    gate, up = jnp.split(gu, 2, axis=-1)
    act = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", act, p["wo"])             # (E, C, d)
    out = constrain(out, ("experts", None, "embed"))

    y = out[ti, slot] * keep[:, None].astype(out.dtype)        # (N*K, d)
    y = y * topv.reshape(N * K, 1).astype(out.dtype)
    y = y.reshape(N, K, d).sum(axis=1)
    return x + y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# Mamba (selective SSM) block
# --------------------------------------------------------------------------

def mamba_spec(d, d_inner, d_state, d_conv, dt_rank):
    return {
        "norm": rms_norm_spec(d),
        "in_proj": P((d, 2 * d_inner), ("embed", "mlp"), init="scaled"),
        "conv_w": P((d_conv, d_inner), (None, "mlp"), init="scaled"),
        "conv_b": P((d_inner,), ("mlp",), init="zeros"),
        "x_proj": P((d_inner, dt_rank + 2 * d_state), ("mlp", None), init="scaled"),
        "dt_proj": P((dt_rank, d_inner), (None, "mlp"), init="scaled"),
        "dt_bias": P((d_inner,), ("mlp",), init="zeros"),
        "a_log": P((d_inner, d_state), ("mlp", None), init="ones"),
        "d_skip": P((d_inner,), ("mlp",), init="ones"),
        "out_proj": P((d_inner, d), ("mlp", "embed"), init="scaled"),
    }


def _mamba_scan(u, dt, a, bmat, cmat, d_skip, h0=None, unroll=8):
    """Sequential selective scan.  u,dt (B,S,di); a (di,st); bmat,cmat (B,S,st).

    Perf notes (§Perf, jamba hillclimb): the naive version materialized
    da = exp(dt*A) of shape (B,S,di,st) BEFORE the scan — 4.3 GB/layer at
    32k prefill and the dominant HBM term of every jamba/mamba cell.  Here
    da/db are recomputed per step from (B,S,di)-sized scan inputs
    (st x less traffic), and `unroll` steps share one state round-trip.
    """
    B, S, di = u.shape
    st = a.shape[-1]
    neg_a = -jnp.exp(a.astype(F32))                            # (di, st)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs                               # (B,di)/(B,st)
        da = jnp.exp(dt_t[..., None] * neg_a[None])            # (B,di,st)
        h = da * h + (dt_t * u_t.astype(F32))[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h_init = jnp.zeros((B, di, st), F32) if h0 is None else h0
    h_last, ys = jax.lax.scan(
        step, h_init,
        (u.transpose(1, 0, 2), dt.astype(F32).transpose(1, 0, 2),
         bmat.astype(F32).transpose(1, 0, 2),
         cmat.astype(F32).transpose(1, 0, 2)),
        unroll=min(unroll, S))
    y = ys.transpose(1, 0, 2) + u.astype(F32) * d_skip[None, None].astype(F32)
    return y, h_last


def mamba_block(p, x, *, d_state, d_conv, dt_rank, eps=1e-5,
                return_state=False, init_state=None):
    from repro.dist.annotate import constrain
    B, S, d = x.shape
    h = rms_norm(p["norm"], x, eps)
    xz = constrain(h @ p["in_proj"], ("batch", None, "mlp"))
    u, z = jnp.split(xz, 2, axis=-1)                           # (B,S,di)
    # causal depthwise conv1d; init_state = (h0, conv_tail (B, d_conv-1, di))
    conv_tail_in = (init_state[1] if init_state is not None else
                    jnp.zeros((B, d_conv - 1, u.shape[-1]), u.dtype))
    upad = jnp.concatenate([conv_tail_in, u], axis=1)
    uc = sum(upad[:, i:i + S] * p["conv_w"][i][None, None]
             for i in range(d_conv)) + p["conv_b"][None, None]
    uc = jax.nn.silu(uc)
    xdbc = uc @ p["x_proj"]
    dt, bmat, cmat = jnp.split(
        xdbc, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"][None, None]).astype(F32)
    h0 = init_state[0] if init_state is not None else None
    y, h_last = _mamba_scan(uc, dt, p["a_log"], bmat, cmat, p["d_skip"], h0=h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = x + y @ p["out_proj"]
    if return_state:
        conv_tail = upad[:, -(d_conv - 1):] if d_conv > 1 else conv_tail_in
        return out, (h_last, conv_tail)
    return out


# --------------------------------------------------------------------------
# RWKV6 (Finch) block
# --------------------------------------------------------------------------

def rwkv_spec(d, ff, n_heads, head_dim, lora=64):
    return {
        "norm_tm": rms_norm_spec(d),
        "norm_cm": rms_norm_spec(d),
        "mu": P((5, d), (None, "embed"), init="small"),        # r,k,v,w,g shift mix
        "wr": P((d, d), ("embed", "heads"), init="scaled"),
        "wk": P((d, d), ("embed", "heads"), init="scaled"),
        "wv": P((d, d), ("embed", "heads"), init="scaled"),
        "wg": P((d, d), ("embed", "heads"), init="scaled"),
        "w0": P((d,), ("embed",), init="zeros"),
        "w_lora_a": P((d, lora), ("embed", None), init="small"),
        "w_lora_b": P((lora, d), (None, "embed"), init="small"),
        "u": P((n_heads, head_dim), ("heads", None), init="small"),
        "ln_x": P((d,), ("embed",), init="ones"),
        "wo": P((d, d), ("heads", "embed"), init="scaled"),
        "mu_cm": P((d,), ("embed",), init="small"),
        "cm_k": P((d, ff), ("embed", "mlp"), init="scaled"),
        "cm_v": P((ff, d), ("mlp", "embed"), init="scaled"),
        "cm_r": P((d, d), ("embed", "embed2"), init="scaled"),
    }


def _token_shift(x, prev=None):
    """x (B,S,d) -> previous-token x (zeros or `prev` at position 0)."""
    sx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        sx = sx.at[:, 0].set(prev)
    return sx


def rwkv_time_mix(p, x, n_heads, head_dim, *, eps=1e-5, wkv_impl="ref",
                  prev_x=None, state=None):
    """Returns (y, (last_x, last_state)).  state (B,H,D,D)."""
    B, S, d = x.shape
    h = rms_norm(p["norm_tm"], x, eps)
    sx = _token_shift(h, prev_x) - h
    from repro.dist.annotate import constrain
    mu = p["mu"]
    xr, xk, xv, xw, xg = (h + sx * mu[i][None, None] for i in range(5))
    r = constrain((xr @ p["wr"]).reshape(B, S, n_heads, head_dim),
                  ("batch", None, "heads", None))
    k = constrain((xk @ p["wk"]).reshape(B, S, n_heads, head_dim),
                  ("batch", None, "heads", None))
    v = constrain((xv @ p["wv"]).reshape(B, S, n_heads, head_dim),
                  ("batch", None, "heads", None))
    g = jax.nn.silu(xg @ p["wg"])
    w_raw = p["w0"][None, None] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(w_raw.astype(F32))).reshape(B, S, n_heads, head_dim)

    if wkv_impl == "pallas":
        from repro.kernels import ops as kops
        y = kops.wkv6_scan(r, k, v, w.astype(r.dtype), p["u"])
        last_state = None                      # pallas path: training only
    else:
        y, last_state = _wkv6_with_state(r, k, v, w, p["u"], state)
    y = y.reshape(B, S, d).astype(F32)
    # per-head group norm
    yh = y.reshape(B, S, n_heads, head_dim)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + eps)
    y = yh.reshape(B, S, d) * p["ln_x"].astype(F32)[None, None]
    y = (y.astype(x.dtype) * g) @ p["wo"]
    return y, (h[:, -1], last_state)


def _wkv6_with_state(r, k, v, w, u, state0):
    B, S, H, D = r.shape
    rf = r.astype(F32).transpose(1, 0, 2, 3)
    kf = k.astype(F32).transpose(1, 0, 2, 3)
    vf = v.astype(F32).transpose(1, 0, 2, 3)
    wf = w.astype(F32).transpose(1, 0, 2, 3)
    uf = u.astype(F32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        y = jnp.einsum("bhk,bhkv->bhv", rt, s)
        y += jnp.einsum("bhk,bhv->bhv", rt * uf[None] * kt, vt)
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, y

    s0 = jnp.zeros((B, H, D, D), F32) if state0 is None else state0
    # NOTE (§Perf, refuted hypothesis): unrolling this scan (unroll=8) was
    # predicted to cut state HBM round-trips 8x but MEASURED 9% worse on the
    # rwkv6 train cell — the (B,T,H,D) xs slices dominate, not the state.
    # The real fix is the Pallas wkv6 kernel (state lives in VMEM).
    s_last, ys = jax.lax.scan(step, s0, (rf, kf, vf, wf))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), s_last


def rwkv_channel_mix(p, x, *, eps=1e-5, prev_x=None):
    h = rms_norm(p["norm_cm"], x, eps)
    sx = _token_shift(h, prev_x) - h
    xk = h + sx * p["mu_cm"][None, None]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    r = jax.nn.sigmoid(h @ p["cm_r"])
    return r * (k @ p["cm_v"]), h[:, -1]


def rwkv_block(p, x, n_heads, head_dim, *, eps=1e-5, wkv_impl="ref",
               return_state=False, init_state=None):
    """init_state/return_state: (tm_shift (B,d), wkv (B,H,D,D), cm_shift (B,d))."""
    tm_prev, wkv0, cm_prev = init_state if init_state is not None else (None,) * 3
    y, (tm_last, s_last) = rwkv_time_mix(
        p, x, n_heads, head_dim, eps=eps,
        wkv_impl="ref" if return_state else wkv_impl,
        prev_x=tm_prev, state=wkv0)
    x = x + y
    y, cm_last = rwkv_channel_mix(p, x, eps=eps, prev_x=cm_prev)
    out = x + y
    if return_state:
        return out, (tm_last, s_last, cm_last)
    return out
