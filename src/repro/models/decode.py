"""Serving paths: KV/state caches, prefill, and single-token decode.

`decode_step` is what `serve_step` lowers for the decode_32k / long_500k
dry-run cells.  Attention layers support two cache-read modes:

  * full      — attend to the whole cache up to `pos` (dense archs);
  * bigbird   — **bounded decode**: the new token reads only the g global
                blocks + the last w window blocks + r random blocks of the
                cache (O(1) per token).  This is the paper's pattern applied
                to autoregressive serving (beyond-paper; see DESIGN.md).

SSM/RWKV layers carry O(1) recurrent state — decode cost independent of
context length, which is why rwkv6/jamba run long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.core.attention import AttentionSpec
from repro.models import layers as L
from repro.models import model as M

F32 = jnp.float32


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def _layer_cache_shapes(cfg: M.ModelConfig, ls: M.LayerSpec, B, max_len,
                        enc_len=0):
    d, dh, hkv = cfg.d_model, cfg.hd, cfg.num_kv_heads
    if ls.kind == "attn":
        c = {"k": ((B, hkv, max_len, dh), cfg.dtype),
             "v": ((B, hkv, max_len, dh), cfg.dtype)}
        if cfg.kind == "encdec":
            c["ck"] = ((B, hkv, enc_len, dh), cfg.dtype)
            c["cv"] = ((B, hkv, enc_len, dh), cfg.dtype)
        return c
    if ls.kind == "mamba":
        di = cfg.mamba_expand * d
        return {"h": ((B, di, cfg.mamba_d_state), F32),
                "conv": ((B, cfg.mamba_conv - 1, di), cfg.dtype)}
    if ls.kind == "rwkv":
        nh = d // cfg.rwkv_head_dim
        return {"tm": ((B, d), cfg.dtype),
                "s": ((B, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim), F32),
                "cm": ((B, d), cfg.dtype)}
    raise ValueError(ls.kind)


def cache_spec(cfg: M.ModelConfig, B, max_len, enc_len=0, abstract=True):
    """Cache tree of ShapeDtypeStructs (abstract) or zeros (concrete)."""
    make = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else \
           (lambda s, dt: jnp.zeros(s, dt))
    pattern, repeats = cfg.layer_pattern, cfg.repeats
    scanned = cfg.scan_layers and repeats > 1
    out = {}
    if scanned:
        for i, ls in enumerate(pattern):
            shapes = _layer_cache_shapes(cfg, ls, B, max_len, enc_len)
            out[f"p{i}"] = {k: make((repeats,) + s, dt)
                            for k, (s, dt) in shapes.items()}
    else:
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            shapes = _layer_cache_shapes(cfg, ls, B, max_len, enc_len)
            out[f"layer{i}"] = {k: make(s, dt) for k, (s, dt) in shapes.items()}
    return out


def cache_logical_axes(cfg: M.ModelConfig, B, max_len, enc_len=0):
    """Logical-axis tree matching cache_spec (for the sharding engine)."""
    def axes_for(key, ndim, stacked):
        base = {
            "k": ("batch", "kv_heads", "seq", None),
            "v": ("batch", "kv_heads", "seq", None),
            "ck": ("batch", "kv_heads", "seq", None),
            "cv": ("batch", "kv_heads", "seq", None),
            "h": ("batch", "mlp", None),
            "conv": ("batch", None, "mlp"),
            "tm": ("batch", "embed"),
            "s": ("batch", "heads", None, None),
            "cm": ("batch", "embed"),
        }[key]
        return (("layers",) + base) if stacked else base

    spec = cache_spec(cfg, B, max_len, enc_len, abstract=True)
    scanned = cfg.scan_layers and cfg.repeats > 1
    return {grp: {k: axes_for(k, v.ndim, scanned) for k, v in leaves.items()}
            for grp, leaves in spec.items()}


# --------------------------------------------------------------------------
# single-token decode
# --------------------------------------------------------------------------

def _full_decode_attn(q, kc, vc, pos, *, upto=None):
    """q (B,Hq,1,dh); kc,vc (B,Hkv,S,dh); attend keys <= pos (or all if None).

    `pos` is a per-slot (B,) vector — every batch row may sit at a different
    sequence position (slot-based continuous batching, serve/batching.py).

    GQA handled with an einsum over (Hkv, grp) WITHOUT materializing the
    repeated cache (the cache is the big operand at 32k/500k)."""
    B, Hq, _, dh = q.shape
    Hkv, S = kc.shape[1], kc.shape[2]
    grp = Hq // Hkv
    qf = q.reshape(B, Hkv, grp, 1, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc,
                        preferred_element_type=F32) / np.sqrt(dh)
    if pos is not None:
        mask = jnp.arange(S)[None] <= pos[:, None]           # (B, S)
        logits = jnp.where(mask[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vc.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vc,
                     preferred_element_type=F32)
    return out.reshape(B, Hq, 1, dh).astype(q.dtype)


def _bigbird_decode_attn(q, kc, vc, pos, bb: patterns.BigBirdConfig, layer):
    """Bounded decode: gather only the pattern's blocks from the cache.

    `pos` (B,) — each slot gathers its own pattern row (heterogeneous
    sequence positions within one batched decode step)."""
    B, Hq, _, dh = q.shape
    Hkv, S = kc.shape[1], kc.shape[2]
    grp = Hq // Hkv
    b = bb.block_size
    pat = patterns.build_pattern(bb, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks)          # (nb, Lslots)
    msk = jnp.asarray(pat.key_mask)
    jq = pos // b                              # (B,)
    row_idx, row_msk = idx[jq], msk[jq]        # (B, Ls)
    flat = (row_idx[..., None] * b + jnp.arange(b)).reshape(B, -1)   # (B,Ls*b)
    kg = jnp.take_along_axis(kc, flat[:, None, :, None], axis=2)
    vg = jnp.take_along_axis(vc, flat[:, None, :, None], axis=2)
    valid = jnp.repeat(row_msk, b, axis=-1) & (flat <= pos[:, None])
    qf = q.reshape(B, Hkv, grp, 1, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kg,
                        preferred_element_type=F32) / np.sqrt(dh)
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vg.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vg,
                     preferred_element_type=F32)
    return out.reshape(B, Hq, 1, dh).astype(q.dtype)


def _decode_attn_layer(p, c, x, cfg: M.ModelConfig, spec: AttentionSpec,
                       layer, pos):
    B = x.shape[0]
    pm = p["mix"]
    h = L.rms_norm(pm["norm"], x, cfg.norm_eps)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    positions = pos[:, None]                              # (B, 1)
    q = (h @ pm["wq"]).reshape(B, 1, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ pm["wk"]).reshape(B, 1, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ pm["wv"]).reshape(B, 1, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    # per-slot cache write: row i lands at its own pos[i]
    write = jax.vmap(
        lambda cr, ur, pr: jax.lax.dynamic_update_slice(cr, ur, (0, pr, 0)))
    kc = write(c["k"], k.astype(c["k"].dtype), pos)
    vc = write(c["v"], v.astype(c["v"].dtype), pos)
    use_bb = spec.kind in ("bigbird", "window")
    if use_bb:
        S = kc.shape[2]
        bb = spec.bigbird_config(S)
        nb = S // bb.block_size if S % bb.block_size == 0 else -1
        if nb < 0 or (bb.num_global_blocks + bb.num_window_blocks
                      + bb.num_random_blocks) > nb:
            use_bb = False                 # cache too short for the pattern
    if use_bb:
        o = _bigbird_decode_attn(q, kc, vc, pos, bb, layer)
    else:
        o = _full_decode_attn(q, kc, vc, pos)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, hq * dh)
    x = x + o @ pm["wo"]
    new_c = dict(c)
    new_c["k"], new_c["v"] = kc, vc

    if cfg.kind == "encdec":                      # cross-attention from cache
        hc = L.rms_norm(p["cross"]["norm"], x, cfg.norm_eps)
        qx = (hc @ p["cross"]["wq"]).reshape(B, 1, hq, dh).transpose(0, 2, 1, 3)
        ox = _full_decode_attn(qx, c["ck"], c["cv"], pos=None)
        ox = ox.transpose(0, 2, 1, 3).reshape(B, 1, hq * dh)
        x = x + ox @ p["cross"]["wo"]
    return x, new_c


def _decode_mamba_layer(p, c, x, cfg: M.ModelConfig):
    pm = p["mix"]
    d_conv, d_state = cfg.mamba_conv, cfg.mamba_d_state
    dt_rank = max(cfg.d_model // 16, 8)
    out, (h_last, conv_tail) = L.mamba_block(
        pm, x, d_state=d_state, d_conv=d_conv, dt_rank=dt_rank,
        eps=cfg.norm_eps, return_state=True,
        init_state=(c["h"], c["conv"]))
    return out, {"h": h_last, "conv": conv_tail.astype(c["conv"].dtype)}


def _decode_rwkv_layer(p, c, x, cfg: M.ModelConfig):
    nh = cfg.d_model // cfg.rwkv_head_dim
    out, (tm, s, cm) = L.rwkv_block(
        p["mix"], x, nh, cfg.rwkv_head_dim, eps=cfg.norm_eps,
        return_state=True, init_state=(c["tm"], c["s"], c["cm"]))
    return out, {"tm": tm.astype(c["tm"].dtype), "s": s,
                 "cm": cm.astype(c["cm"].dtype)}


def _decode_layer(p, c, x, cfg, ls: M.LayerSpec, layer, pos):
    if ls.kind == "attn":
        x, new_c = _decode_attn_layer(p, c, x, cfg, cfg.attn_spec(ls), layer, pos)
    elif ls.kind == "mamba":
        x, new_c = _decode_mamba_layer(p, c, x, cfg)
    elif ls.kind == "rwkv":
        x, new_c = _decode_rwkv_layer(p, c, x, cfg)
        return x, new_c                            # rwkv ffn is inside block
    else:
        raise ValueError(ls.kind)
    if "ffn" in p:
        if ls.moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, new_c


def decode_step(params, cfg: M.ModelConfig, cache, tokens, pos):
    """tokens (B, 1) int32; pos () or (B,) int32 -> (logits (B, V) f32, cache).

    Scalar `pos` (all slots at the same position) is broadcast; a (B,)
    vector gives every slot its own position — the contract the serving
    Engine's slot pool (repro/serve/batching.py) relies on."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((tokens.shape[0],), pos)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    stack = params["decoder"] if cfg.kind == "encdec" else params["layers"]
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, xs):
            pslice, cslice = xs
            new_c = {}
            for i, ls in enumerate(pattern):
                x, nc = _decode_layer(pslice[f"p{i}"], cslice[f"p{i}"],
                                      x, cfg, ls, i, pos)
                new_c[f"p{i}"] = nc
            return x, new_c
        x, new_cache = jax.lax.scan(body, x, (stack, cache))
    else:
        new_cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            x, nc = _decode_layer(stack[f"layer{i}"], cache[f"layer{i}"],
                                  x, cfg, ls, i, pos)
            new_cache[f"layer{i}"] = nc
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    logits = (x[:, 0] @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, new_cache


# --------------------------------------------------------------------------
# prefill (forward pass that also fills the caches)
# --------------------------------------------------------------------------

def _prefill_layer(p, x, cfg, ls, layer, positions, max_len, enc_kv=None):
    B, S, _ = x.shape
    if ls.kind == "attn":
        out, (k, v) = L.attn_block(
            p["mix"], x, cfg.attn_spec(ls), cfg.num_heads, cfg.num_kv_heads,
            cfg.hd, positions=positions, theta=cfg.rope_theta, layer=layer,
            eps=cfg.norm_eps, return_kv=True)
        pad = max_len - S
        c = {"k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cfg.dtype),
             "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cfg.dtype)}
        if enc_kv is not None:
            out = L.attn_block(p["cross"], out,
                               AttentionSpec(kind="full", causal=False),
                               cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                               positions=None, eps=cfg.norm_eps,
                               kv_override=enc_kv)
            c["ck"], c["cv"] = (enc_kv[0].astype(cfg.dtype),
                                enc_kv[1].astype(cfg.dtype))
        x = out
    elif ls.kind == "mamba":
        dt_rank = max(cfg.d_model // 16, 8)
        x, (h_last, tail) = L.mamba_block(
            p["mix"], x, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_conv,
            dt_rank=dt_rank, eps=cfg.norm_eps, return_state=True)
        c = {"h": h_last, "conv": tail.astype(cfg.dtype)}
    elif ls.kind == "rwkv":
        nh = cfg.d_model // cfg.rwkv_head_dim
        x, (tm, s, cm) = L.rwkv_block(p["mix"], x, nh, cfg.rwkv_head_dim,
                                      eps=cfg.norm_eps, return_state=True)
        return x, {"tm": tm.astype(cfg.dtype), "s": s, "cm": cm.astype(cfg.dtype)}
    else:
        raise ValueError(ls.kind)
    if "ffn" in p:
        if ls.moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, c


def prefill(params, cfg: M.ModelConfig, batch, max_len, last_index=None):
    """Run the prompt through the model, returning (last-token logits, cache).

    For encdec, batch must contain "frames" (encoder input) and "tokens"
    (decoder prompt); cache includes per-layer cross K/V.

    `last_index` (B,) int32: per-row index of the last *real* prompt token.
    The Engine right-pads prompts to a bucketed length before prefill;
    under causal attention the padded tail cannot influence positions
    <= last_index, so gathering logits there (instead of at -1) makes
    bucketed prefill exact.  None keeps the original "last column" output.
    """
    enc_h = None
    if cfg.kind == "encdec":
        enc_h, _ = M._encoder_hidden(params, cfg, batch["frames"])
        stack = params["decoder"]
    else:
        stack = params["layers"]
    x = M._embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, pslice):
            cs = {}
            for i, ls in enumerate(pattern):
                enc_kv = (L.cross_kv(pslice[f"p{i}"]["cross"], enc_h,
                                     cfg.num_kv_heads, cfg.hd)
                          if enc_h is not None else None)
                x, c = _prefill_layer(pslice[f"p{i}"], x, cfg, ls, i,
                                      positions, max_len, enc_kv)
                cs[f"p{i}"] = c
            return x, cs
        x, cache = jax.lax.scan(body, x, stack)
    else:
        cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            p = stack[f"layer{i}"]
            enc_kv = (L.cross_kv(p["cross"], enc_h, cfg.num_kv_heads, cfg.hd)
                      if enc_h is not None else None)
            x, c = _prefill_layer(p, x, cfg, ls, i, positions, max_len, enc_kv)
            cache[f"layer{i}"] = c
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    if last_index is None:
        h_last = x[:, -1]
    else:
        idx = jnp.asarray(last_index, jnp.int32)[:, None, None]
        h_last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
    logits = (h_last @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, cache
