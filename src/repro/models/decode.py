"""Serving paths: KV/state caches, prefill, and single-token decode.

`decode_step` is what `serve_step` lowers for the decode_32k / long_500k
dry-run cells.  Attention layers support two cache-read modes:

  * full      — attend to the whole cache up to `pos` (dense archs);
  * bigbird   — **bounded decode**: the new token reads only the g global
                blocks + the last w window blocks + r random blocks of the
                cache (O(1) per token).  This is the paper's pattern applied
                to autoregressive serving (beyond-paper; see DESIGN.md).

SSM/RWKV layers carry O(1) recurrent state — decode cost independent of
context length, which is why rwkv6/jamba run long_500k natively.

Paged KV layout (DESIGN.md §Paged cache): when `cache_spec` is built with
`num_pages=`, every attention K/V leaf becomes ONE flat physical store
`(num_pages, Hkv, page_size, dh)` shared by all requests — page size equals
the BigBird pattern block size `b`, so one pattern block is one page and the
bounded-decode gather becomes a two-level lookup: pattern block -> page
table -> physical page.  `decode_step(..., page_tables=)` and
`prefill_chunk` are the paged entry points; recurrent-state leaves keep
their per-slot `(B, ...)` layout (they are O(1) per slot already).

Quantized pages (`kv_dtype=int8`): the K/V stores become int8 with one f32
scale per (page, kv head) in sibling leaves `ks`/`vs` `(num_pages, Hkv)`.
Writers quantize whole pages (absmax/127 per page+head, clamped); readers
dequantize right after the page gather, in f32, before any contraction.
Single-token decode/verify writes read-modify-requantize the whole page
with a MONOTONE scale (max of old scale and the new token's), so already
written rows requantize exactly whenever the scale is unchanged; the first
row of a page (offset 0) resets the page, making its int8 content a pure
function of the tokens written since mapping — the property prefix-page
content-addressing relies on.  Quantization is lossy: chunked == one-shot
and verify == sequential contracts hold only approximately under int8 (the
serving bench gates an NLL delta instead of bit equality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.core.attention import AttentionSpec
from repro.models import layers as L
from repro.models import model as M

F32 = jnp.float32

# Per-(page, head) quantization scales never go below this: a page of exact
# zeros must still dequantize to exact zeros with a finite scale.
INT8_SCALE_EPS = 1e-8


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def page_size_for(cfg: M.ModelConfig) -> int:
    """Page size of the paged KV layout: the attention pattern block size.

    All attention layers of a config must agree on block_size (one physical
    page granularity per pool); configs with no attention layers have no
    paged leaves and the value is only a placeholder."""
    sizes = {cfg.attn_spec(ls).block_size
             for ls in cfg.layer_pattern if ls.kind == "attn"}
    assert len(sizes) <= 1, f"mixed attention block sizes {sizes} cannot page"
    return sizes.pop() if sizes else 64


def _layer_cache_shapes(cfg: M.ModelConfig, ls: M.LayerSpec, B, max_len,
                        enc_len=0, num_pages=None, kv_dtype=None):
    d, dh, hkv = cfg.d_model, cfg.hd, cfg.num_kv_heads
    if ls.kind == "attn":
        if num_pages is not None:
            assert cfg.kind != "encdec", "paged cache is decoder-only"
            b = page_size_for(cfg)
            dt = cfg.dtype if kv_dtype is None else jnp.dtype(kv_dtype)
            c = {"k": ((num_pages, hkv, b, dh), dt),
                 "v": ((num_pages, hkv, b, dh), dt)}
            if dt == jnp.int8:
                # one f32 scale per (page, kv head); sibling leaves so the
                # pages axis shards identically to the stores they scale
                c["ks"] = ((num_pages, hkv), F32)
                c["vs"] = ((num_pages, hkv), F32)
            return c
        c = {"k": ((B, hkv, max_len, dh), cfg.dtype),
             "v": ((B, hkv, max_len, dh), cfg.dtype)}
        if cfg.kind == "encdec":
            c["ck"] = ((B, hkv, enc_len, dh), cfg.dtype)
            c["cv"] = ((B, hkv, enc_len, dh), cfg.dtype)
        return c
    if ls.kind == "mamba":
        di = cfg.mamba_expand * d
        return {"h": ((B, di, cfg.mamba_d_state), F32),
                "conv": ((B, cfg.mamba_conv - 1, di), cfg.dtype)}
    if ls.kind == "rwkv":
        nh = d // cfg.rwkv_head_dim
        return {"tm": ((B, d), cfg.dtype),
                "s": ((B, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim), F32),
                "cm": ((B, d), cfg.dtype)}
    raise ValueError(ls.kind)


def cache_spec(cfg: M.ModelConfig, B, max_len, enc_len=0, abstract=True,
               num_pages=None, kv_dtype=None):
    """Cache tree of ShapeDtypeStructs (abstract) or zeros (concrete).

    ``num_pages`` switches the attention K/V leaves to the paged layout —
    one flat `(num_pages, Hkv, page_size, dh)` physical store (no batch
    dim: pages are pool-global and mapped per request by a page table).
    Recurrent-state leaves keep the per-slot `(B, ...)` layout.

    ``kv_dtype`` overrides the paged stores' dtype; `int8` additionally
    adds the per-(page, head) f32 scale leaves `ks`/`vs`."""
    make = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else \
           (lambda s, dt: jnp.zeros(s, dt))
    pattern, repeats = cfg.layer_pattern, cfg.repeats
    scanned = cfg.scan_layers and repeats > 1
    out = {}
    if scanned:
        for i, ls in enumerate(pattern):
            shapes = _layer_cache_shapes(cfg, ls, B, max_len, enc_len,
                                         num_pages, kv_dtype)
            out[f"p{i}"] = {k: make((repeats,) + s, dt)
                            for k, (s, dt) in shapes.items()}
    else:
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            shapes = _layer_cache_shapes(cfg, ls, B, max_len, enc_len,
                                         num_pages, kv_dtype)
            out[f"layer{i}"] = {k: make(s, dt) for k, (s, dt) in shapes.items()}
    return out


def cache_logical_axes(cfg: M.ModelConfig, B, max_len, enc_len=0,
                       num_pages=None, kv_dtype=None):
    """Logical-axis tree matching cache_spec (for the sharding engine)."""
    paged_kv = num_pages is not None

    def axes_for(key, ndim, stacked):
        base = {
            # paged K/V: pages split over data (per-shard sub-pools with
            # local page-id spaces), kv heads over model (tensor parallel)
            "k": (("pages", "kv_heads", None, None) if paged_kv
                  else ("batch", "kv_heads", "seq", None)),
            "v": (("pages", "kv_heads", None, None) if paged_kv
                  else ("batch", "kv_heads", "seq", None)),
            # int8 page scales follow their stores: pages -> data,
            # kv heads -> model
            "ks": ("pages", "kv_heads"),
            "vs": ("pages", "kv_heads"),
            "ck": ("batch", "kv_heads", "seq", None),
            "cv": ("batch", "kv_heads", "seq", None),
            "h": ("batch", "mlp", None),
            "conv": ("batch", None, "mlp"),
            "tm": ("batch", "embed"),
            "s": ("batch", "heads", None, None),
            "cm": ("batch", "embed"),
        }[key]
        return (("layers",) + base) if stacked else base

    spec = cache_spec(cfg, B, max_len, enc_len, abstract=True,
                      num_pages=num_pages, kv_dtype=kv_dtype)
    scanned = cfg.scan_layers and cfg.repeats > 1
    return {grp: {k: axes_for(k, v.ndim, scanned) for k, v in leaves.items()}
            for grp, leaves in spec.items()}


# --------------------------------------------------------------------------
# mesh-parallel head slicing (DESIGN.md §Mesh-parallel serving)
# --------------------------------------------------------------------------

def _local_heads(q, k, v, kv_leaf, model_axis):
    """Slice the model shard's head range out of full q/k/v projections.

    Inside `shard_map` over a (data, model) mesh the paged K/V leaf carries
    only this shard's kv heads (`kv_leaf.shape[-3]` = Hkv / model), while
    the projections q/k/v (B, H, T, dh) were computed at FULL width from
    replicated params — bit-identical to the unsharded run by construction
    (each output column of a matmul is an independent dot product, and
    slicing selects columns).  Query heads are grouped per kv head
    (h_q = h_kv * grp + g), so one contiguous slice serves GQA too.
    Returns (q_local, k_local, v_local)."""
    hq, hkv = q.shape[1], k.shape[1]
    hkv_l = kv_leaf.shape[-3]
    hq_l = hkv_l * (hq // hkv)
    m = jax.lax.axis_index(model_axis)
    q = jax.lax.dynamic_slice_in_dim(q, m * hq_l, hq_l, 1)
    k = jax.lax.dynamic_slice_in_dim(k, m * hkv_l, hkv_l, 1)
    v = jax.lax.dynamic_slice_in_dim(v, m * hkv_l, hkv_l, 1)
    return q, k, v


def _gather_heads(o, model_axis):
    """Reassemble the full per-head attention output across the model axis
    (shard m contributed heads [m*hq_l, (m+1)*hq_l) — tiled all_gather
    concatenates in axis order, restoring the replicated layout exactly)."""
    return jax.lax.all_gather(o, model_axis, axis=1, tiled=True)


# --------------------------------------------------------------------------
# single-token decode
# --------------------------------------------------------------------------

def _full_decode_attn(q, kc, vc, pos, *, upto=None):
    """q (B,Hq,1,dh); kc,vc (B,Hkv,S,dh); attend keys <= pos (or all if None).

    `pos` is a per-slot (B,) vector — every batch row may sit at a different
    sequence position (slot-based continuous batching, serve/batching.py).

    GQA handled with an einsum over (Hkv, grp) WITHOUT materializing the
    repeated cache (the cache is the big operand at 32k/500k)."""
    B, Hq, _, dh = q.shape
    Hkv, S = kc.shape[1], kc.shape[2]
    grp = Hq // Hkv
    qf = q.reshape(B, Hkv, grp, 1, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc,
                        preferred_element_type=F32) / np.sqrt(dh)
    if pos is not None:
        mask = jnp.arange(S)[None] <= pos[:, None]           # (B, S)
        logits = jnp.where(mask[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vc.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vc,
                     preferred_element_type=F32)
    return out.reshape(B, Hq, 1, dh).astype(q.dtype)


def _bigbird_decode_attn(q, kc, vc, pos, bb: patterns.BigBirdConfig, layer):
    """Bounded decode: gather only the pattern's blocks from the cache.

    `pos` (B,) — each slot gathers its own pattern row (heterogeneous
    sequence positions within one batched decode step)."""
    B, Hq, _, dh = q.shape
    Hkv, S = kc.shape[1], kc.shape[2]
    grp = Hq // Hkv
    b = bb.block_size
    pat = patterns.build_pattern(bb, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks)          # (nb, Lslots)
    msk = jnp.asarray(pat.key_mask)
    jq = pos // b                              # (B,)
    row_idx, row_msk = idx[jq], msk[jq]        # (B, Ls)
    flat = (row_idx[..., None] * b + jnp.arange(b)).reshape(B, -1)   # (B,Ls*b)
    kg = jnp.take_along_axis(kc, flat[:, None, :, None], axis=2)
    vg = jnp.take_along_axis(vc, flat[:, None, :, None], axis=2)
    valid = jnp.repeat(row_msk, b, axis=-1) & (flat <= pos[:, None])
    qf = q.reshape(B, Hkv, grp, 1, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kg,
                        preferred_element_type=F32) / np.sqrt(dh)
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vg.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vg,
                     preferred_element_type=F32)
    return out.reshape(B, Hq, 1, dh).astype(q.dtype)


def _paged_gather(kc, page_tables, blocks, scale=None):
    """Two-level gather: logical blocks -> physical pages -> key rows.

    kc (P, H, b, dh) physical page store; page_tables (B, max_pages) int32;
    blocks (B, n) logical block ids.  Returns (B, H, n*b, dh) laid out in
    the same slot-major order as the contiguous gather, so downstream math
    is bit-identical to the slot-contiguous path.

    `scale` (P, H) — int8 stores' per-(page, head) scales: gathered through
    the same table and multiplied in right after the page gather (the f32
    dequant happens before any contraction touches the rows)."""
    phys = jnp.take_along_axis(page_tables, blocks, axis=1)       # (B, n)
    g = kc[phys]                                         # (B, n, H, b, dh)
    if scale is not None:
        g = g.astype(F32) * scale[phys][..., None, None]
    B, n, H, b, dh = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, H, n * b, dh)


def _quantize_pages(x):
    """Quantize page blocks x (..., b, dh) f32 -> (int8 blocks, f32 scales).

    Scale is absmax over the page's (b, dh) rows / 127 per leading index
    (page, head), clamped to INT8_SCALE_EPS so all-zero pages stay exact."""
    x = x.astype(F32)
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=(-2, -1)) / 127.0,
                    INT8_SCALE_EPS)
    q = jnp.clip(jnp.round(x / s[..., None, None]), -127, 127) \
        .astype(jnp.int8)
    return q, s


def _scatter_pages(c, key, phys_w, blocks):
    """Scatter whole page blocks into the (possibly quantized) store `key`.

    c — layer cache dict; phys_w (B, nc) physical page rows; blocks
    (B, nc, H, b, dh).  Returns the updated leaves as a dict ({key} or
    {key, key+"s"} when the store is int8-paged)."""
    store = c[key]
    if key + "s" in c:
        q, s = _quantize_pages(blocks)
        return {key: store.at[phys_w].set(q.astype(store.dtype)),
                key + "s": c[key + "s"].at[phys_w].set(s)}
    return {key: store.at[phys_w].set(blocks.astype(store.dtype))}


def _paged_write_token(kc, k_new, page_tables, pos):
    """Write one token's KV at its logical `pos` through the page table.

    kc (P, H, b, dh); k_new (B, H, dh); pos (B,).  Each slot writes its own
    page — pages are never shared between writers (copy-on-write is resolved
    host-side before the step; see serve/batching.PagePool)."""
    b = kc.shape[2]
    pg = jnp.take_along_axis(page_tables, (pos // b)[:, None], axis=1)[:, 0]
    return kc.at[pg, :, pos % b].set(k_new.astype(kc.dtype))


def _quant_token_write(kc, ks, k_new, pg, off, *, drop=False):
    """Single-token write into an int8 page: read-modify-requantize.

    kc (P, H, b, dh) int8; ks (P, H) f32; k_new (B, H, dh); pg (B,)
    physical pages (== P for dropped writes when `drop`); off (B,) row
    offset inside the page.  The page rescales MONOTONICALLY —
    `new_scale = max(old_scale, token_absmax/127)` — so previously written
    rows requantize exactly whenever the scale is unchanged.  `off == 0`
    resets the page (a page's first write always lands at row 0: decode
    maps a fresh page exactly when pos crosses a block boundary, and
    rollback never leaves live rows above the write position), which makes
    the int8 bytes a pure function of the tokens written since mapping —
    stale content of a recycled physical page cannot leak into scales."""
    P, _, b, _ = kc.shape
    B = pg.shape[0]
    safe = jnp.clip(pg, 0, P - 1)
    old_s = jnp.where((off == 0)[:, None], 0.0, ks[safe])        # (B, H)
    page = kc[safe].astype(F32) * old_s[..., None, None]         # (B,H,b,dh)
    row = jax.lax.broadcasted_iota(jnp.int32, (1, 1, b, 1), 2) \
        == off[:, None, None, None]
    page = jnp.where(row, k_new.astype(F32)[:, :, None, :], page)
    tok_s = jnp.max(jnp.abs(k_new.astype(F32)), axis=-1) / 127.0  # (B, H)
    new_s = jnp.maximum(jnp.maximum(old_s, tok_s), INT8_SCALE_EPS)
    q = jnp.clip(jnp.round(page / new_s[..., None, None]), -127, 127) \
        .astype(kc.dtype)
    mode = "drop" if drop else "promise_in_bounds"
    return kc.at[pg].set(q, mode=mode), ks.at[pg].set(new_s, mode=mode)


def _bigbird_decode_attn_paged(q, kc, vc, page_tables, pos,
                               bb: patterns.BigBirdConfig, layer, impl,
                               k_scale=None, v_scale=None):
    """Bounded decode over the paged cache: pattern blocks -> page table ->
    physical pages.  XLA-gather baseline; `impl="pallas"` dispatches to the
    scalar-prefetched Pallas paged-decode kernel (forward-only).
    `k_scale`/`v_scale` (P, Hkv) dequantize int8 stores after the gather."""
    if impl == "pallas":
        from repro.kernels import ops                      # lazy import
        return ops.bigbird_paged_decode_attn(q, kc, vc, page_tables, pos,
                                             bb, layer=layer,
                                             k_scale=k_scale, v_scale=v_scale)
    B, Hq, _, dh = q.shape
    b = bb.block_size
    S = page_tables.shape[1] * b
    Hkv = kc.shape[1]
    grp = Hq // Hkv
    pat = patterns.build_pattern(bb, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks)          # (nb, Lslots)
    msk = jnp.asarray(pat.key_mask)
    jq = pos // b                              # (B,)
    row_idx, row_msk = idx[jq], msk[jq]        # (B, Ls)
    kg = _paged_gather(kc, page_tables, row_idx, k_scale)
    vg = _paged_gather(vc, page_tables, row_idx, v_scale)
    flat = (row_idx[..., None] * b + jnp.arange(b)).reshape(B, -1)   # (B,Ls*b)
    valid = jnp.repeat(row_msk, b, axis=-1) & (flat <= pos[:, None])
    qf = q.reshape(B, Hkv, grp, 1, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kg,
                        preferred_element_type=F32) / np.sqrt(dh)
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vg.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vg,
                     preferred_element_type=F32)
    return out.reshape(B, Hq, 1, dh).astype(q.dtype)


def _full_decode_attn_paged(q, kc, vc, page_tables, pos,
                            k_scale=None, v_scale=None):
    """Full-fallback read over the paged cache: gather every logical block
    in order, then run the standard masked dense read (bit-identical to the
    slot-contiguous fallback)."""
    B = q.shape[0]
    n = page_tables.shape[1]
    blocks = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (B, n))
    kg = _paged_gather(kc, page_tables, blocks, k_scale)
    vg = _paged_gather(vc, page_tables, blocks, v_scale)
    return _full_decode_attn(q, kg, vg, pos)


def _decode_attn_layer(p, c, x, cfg: M.ModelConfig, spec: AttentionSpec,
                       layer, pos, page_tables=None, model_axis=None):
    B = x.shape[0]
    pm = p["mix"]
    h = L.rms_norm(pm["norm"], x, cfg.norm_eps)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    positions = pos[:, None]                              # (B, 1)
    q = (h @ pm["wq"]).reshape(B, 1, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ pm["wk"]).reshape(B, 1, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ pm["wv"]).reshape(B, 1, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if model_axis is not None:
        assert page_tables is not None, \
            "mesh-parallel decode runs over the paged cache"
        q, k, v = _local_heads(q, k, v, c["k"], model_axis)
    if page_tables is None:
        # per-slot cache write: row i lands at its own pos[i]
        write = jax.vmap(
            lambda cr, ur, pr: jax.lax.dynamic_update_slice(cr, ur, (0, pr, 0)))
        kc = write(c["k"], k.astype(c["k"].dtype), pos)
        vc = write(c["v"], v.astype(c["v"].dtype), pos)
        S = kc.shape[2]
    else:
        if "ks" in c:                          # int8 pages: RMW-requantize
            b_pg = c["k"].shape[-2]
            pg = jnp.take_along_axis(page_tables, (pos // b_pg)[:, None],
                                     axis=1)[:, 0]
            kc, ks = _quant_token_write(c["k"], c["ks"], k[:, :, 0], pg,
                                        pos % b_pg)
            vc, vs = _quant_token_write(c["v"], c["vs"], v[:, :, 0], pg,
                                        pos % b_pg)
        else:
            kc = _paged_write_token(c["k"], k[:, :, 0], page_tables, pos)
            vc = _paged_write_token(c["v"], v[:, :, 0], page_tables, pos)
            ks = vs = None
        S = page_tables.shape[1] * kc.shape[2]
    use_bb = spec.kind in ("bigbird", "window")
    if use_bb:
        bb = spec.bigbird_config(S)
        nb = S // bb.block_size if S % bb.block_size == 0 else -1
        if not patterns.fits(bb, nb):
            use_bb = False                 # cache too short for the pattern
    if page_tables is not None:
        if use_bb:
            o = _bigbird_decode_attn_paged(q, kc, vc, page_tables, pos, bb,
                                           layer, spec.impl, ks, vs)
        else:
            o = _full_decode_attn_paged(q, kc, vc, page_tables, pos, ks, vs)
    elif use_bb:
        o = _bigbird_decode_attn(q, kc, vc, pos, bb, layer)
    else:
        o = _full_decode_attn(q, kc, vc, pos)
    if model_axis is not None:
        o = _gather_heads(o, model_axis)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, hq * dh)
    x = x + o @ pm["wo"]
    new_c = dict(c)
    new_c["k"], new_c["v"] = kc, vc
    if "ks" in c:
        new_c["ks"], new_c["vs"] = ks, vs

    if cfg.kind == "encdec":                      # cross-attention from cache
        hc = L.rms_norm(p["cross"]["norm"], x, cfg.norm_eps)
        qx = (hc @ p["cross"]["wq"]).reshape(B, 1, hq, dh).transpose(0, 2, 1, 3)
        ox = _full_decode_attn(qx, c["ck"], c["cv"], pos=None)
        ox = ox.transpose(0, 2, 1, 3).reshape(B, 1, hq * dh)
        x = x + ox @ p["cross"]["wo"]
    return x, new_c


def _decode_mamba_layer(p, c, x, cfg: M.ModelConfig):
    pm = p["mix"]
    d_conv, d_state = cfg.mamba_conv, cfg.mamba_d_state
    dt_rank = max(cfg.d_model // 16, 8)
    out, (h_last, conv_tail) = L.mamba_block(
        pm, x, d_state=d_state, d_conv=d_conv, dt_rank=dt_rank,
        eps=cfg.norm_eps, return_state=True,
        init_state=(c["h"], c["conv"]))
    return out, {"h": h_last, "conv": conv_tail.astype(c["conv"].dtype)}


def _decode_rwkv_layer(p, c, x, cfg: M.ModelConfig):
    nh = cfg.d_model // cfg.rwkv_head_dim
    out, (tm, s, cm) = L.rwkv_block(
        p["mix"], x, nh, cfg.rwkv_head_dim, eps=cfg.norm_eps,
        return_state=True, init_state=(c["tm"], c["s"], c["cm"]))
    return out, {"tm": tm.astype(c["tm"].dtype), "s": s,
                 "cm": cm.astype(c["cm"].dtype)}


def _decode_layer(p, c, x, cfg, ls: M.LayerSpec, layer, pos, page_tables=None,
                  model_axis=None):
    if ls.kind == "attn":
        x, new_c = _decode_attn_layer(p, c, x, cfg, cfg.attn_spec(ls), layer,
                                      pos, page_tables, model_axis)
    elif ls.kind == "mamba":
        x, new_c = _decode_mamba_layer(p, c, x, cfg)
    elif ls.kind == "rwkv":
        x, new_c = _decode_rwkv_layer(p, c, x, cfg)
        return x, new_c                            # rwkv ffn is inside block
    else:
        raise ValueError(ls.kind)
    if "ffn" in p:
        if ls.moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, new_c


def decode_step(params, cfg: M.ModelConfig, cache, tokens, pos,
                page_tables=None, model_axis=None):
    """tokens (B, 1) int32; pos () or (B,) int32 -> (logits (B, V) f32, cache).

    Scalar `pos` (all slots at the same position) is broadcast; a (B,)
    vector gives every slot its own position — the contract the serving
    Engine's slot pool (repro/serve/batching.py) relies on.

    `page_tables` (B, max_pages) int32 selects the paged cache layout: the
    cache tree must come from `cache_spec(..., num_pages=)`, each row maps
    that slot's logical blocks to physical pages, and the attention
    write/read go through the table (DESIGN.md §Paged cache).

    `model_axis` names the tensor-parallel mesh axis when this runs inside
    `shard_map`: the paged K/V leaves then carry only the shard's local kv
    heads, attention computes on that head slice, and the per-head outputs
    are all-gathered before the output projection — everything else is
    replicated full-width math, keeping the sharded step bit-identical to
    the unsharded one (DESIGN.md §Mesh-parallel serving)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((tokens.shape[0],), pos)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    stack = params["decoder"] if cfg.kind == "encdec" else params["layers"]
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, xs):
            pslice, cslice = xs
            new_c = {}
            for i, ls in enumerate(pattern):
                x, nc = _decode_layer(pslice[f"p{i}"], cslice[f"p{i}"],
                                      x, cfg, ls, i, pos, page_tables,
                                      model_axis)
                new_c[f"p{i}"] = nc
            return x, new_c
        x, new_cache = jax.lax.scan(body, x, (stack, cache))
    else:
        new_cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            x, nc = _decode_layer(stack[f"layer{i}"], cache[f"layer{i}"],
                                  x, cfg, ls, i, pos, page_tables,
                                  model_axis)
            new_cache[f"layer{i}"] = nc
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    logits = (x[:, 0] @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, new_cache


# --------------------------------------------------------------------------
# chunked prefill into the paged cache
# --------------------------------------------------------------------------

def _chunk_attn_layer(p, c, x, cfg: M.ModelConfig, spec: AttentionSpec,
                      layer, page_tables, start: int, bucket_len: int,
                      write_tables=None, model_axis=None):
    """One attention layer of a prefill chunk covering positions
    [start, start+C), reading/writing the paged cache.

    `start` is STATIC (chunk launches are compiled per chunk offset) so
    every gather has a fixed shape and the pattern-row/causal masks are
    host-side constants.  `bucket_len` is the padded length the ONE-SHOT
    prefill of this prompt would run at: the per-layer BigBird-vs-full
    fallback decision is made against it, exactly mirroring
    core.attention() — chunked and one-shot prefill therefore build the
    same graph.  Under causal attention the math then matches one-shot
    prefill bit-for-bit: a query at position p attends exactly the keys
    <= p that the pattern admits, regardless of how the prompt was split
    into chunks (masked scores contribute exactly 0)."""
    assert spec.causal, "chunked prefill is causal-only (decoder LM serving)"
    B, C, _ = x.shape
    pm = p["mix"]
    h = L.rms_norm(pm["norm"], x, cfg.norm_eps)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    positions = start + jnp.arange(C)
    q = (h @ pm["wq"]).reshape(B, C, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ pm["wk"]).reshape(B, C, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ pm["wv"]).reshape(B, C, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    hq_full = hq
    if model_axis is not None:
        q, k, v = _local_heads(q, k, v, c["k"], model_axis)
        hq, hkv = q.shape[1], k.shape[1]       # local head counts

    b = c["k"].shape[-2]                       # physical page size
    assert C % b == 0 and start % b == 0, (C, start, b)
    nc, qb0 = C // b, start // b
    assert qb0 + nc <= page_tables.shape[1], \
        f"chunk [{start},{start + C}) crosses the logical cache end"
    grp = hq // hkv
    # scatter this chunk's KV blocks into the slot's pages; `write_tables`
    # (default: the read tables) lets the caller redirect blocks it must
    # not touch — prefix-SHARED pages — to the dump page
    wt = page_tables if write_tables is None else write_tables
    phys_w = wt[:, qb0:qb0 + nc]                                 # (B, nc)
    as_blocks = lambda t: t.reshape(B, hkv, nc, b, dh).transpose(0, 2, 1, 3, 4)
    upd = {**_scatter_pages(c, "k", phys_w, as_blocks(k)),
           **_scatter_pages(c, "v", phys_w, as_blocks(v))}
    kc, vc = upd["k"], upd["v"]
    ks, vs = upd.get("ks"), upd.get("vs")

    # the same fallback rule core.attention() applies at the one-shot
    # bucket: pattern larger than the (padded) prompt -> exact full attn
    use_bb = spec.kind in ("bigbird", "window")
    if use_bb:
        bb = spec.bigbird_config(bucket_len)
        nbk = bucket_len // b if bucket_len % b == 0 else -1
        if not patterns.fits(bb, nbk):
            use_bb = False

    end = start + C
    if use_bb:
        S_log = page_tables.shape[1] * b
        pat = patterns.build_pattern(bb, S_log, layer=layer)
        rows = pat.key_blocks[qb0:qb0 + nc]                      # (nc, Ls) np
        rmsk = pat.key_mask[qb0:qb0 + nc]
        Ls = rows.shape[1]
        blocks = jnp.broadcast_to(
            jnp.asarray(rows.reshape(-1), jnp.int32)[None], (B, nc * Ls))
        kg = _paged_gather(kc, page_tables, blocks, ks).reshape(B, hkv, nc,
                                                                Ls * b, dh)
        vg = _paged_gather(vc, page_tables, blocks, vs).reshape(B, hkv, nc,
                                                                Ls * b, dh)
        flat = (rows[..., None] * b + np.arange(b)).reshape(nc, Ls * b)
        qpos = (start + np.arange(C)).reshape(nc, b)
        valid = (np.repeat(rmsk, b, axis=1)[:, None, :]
                 & (flat[:, None, :] <= qpos[:, :, None]))       # (nc,b,Ls*b)
        qf = q.reshape(B, hkv, grp, nc, b, dh)
        s = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qf, kg,
                       preferred_element_type=F32) / np.sqrt(dh)
        s = jnp.where(jnp.asarray(valid)[None, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
        o = jnp.einsum("bhgnqk,bhnkd->bhgnqd", pr, vg,
                       preferred_element_type=F32)
        o = o.reshape(B, hq, C, dh).astype(q.dtype)
        # global *query* rows attend densely to everything <= their position
        gb = bb.num_global_blocks
        if qb0 < gb:
            ngb = min(gb - qb0, nc)
            pre = jnp.broadcast_to(
                jnp.arange(end // b, dtype=jnp.int32)[None], (B, end // b))
            ka = _paged_gather(kc, page_tables, pre, ks)         # (B,H,end,dh)
            va = _paged_gather(vc, page_tables, pre, vs)
            qg = q[:, :, :ngb * b].reshape(B, hkv, grp, ngb * b, dh)
            sg = jnp.einsum("bhgqd,bhkd->bhgqk", qg, ka,
                            preferred_element_type=F32) / np.sqrt(dh)
            cm = (start + np.arange(ngb * b))[:, None] >= np.arange(end)[None]
            sg = jnp.where(jnp.asarray(cm)[None, None, None], sg, -1e30)
            pg = jax.nn.softmax(sg, axis=-1).astype(va.dtype)
            og = jnp.einsum("bhgqk,bhkd->bhgqd", pg, va,
                            preferred_element_type=F32)
            og = og.reshape(B, hq, ngb * b, dh)
            o = o.at[:, :, :ngb * b].set(og.astype(o.dtype))
    else:
        # pattern does not fit the prompt bucket: exact full causal attention
        pre = jnp.broadcast_to(
            jnp.arange(end // b, dtype=jnp.int32)[None], (B, end // b))
        ka = _paged_gather(kc, page_tables, pre, ks)
        va = _paged_gather(vc, page_tables, pre, vs)
        qf = q.reshape(B, hkv, grp, C, dh)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, ka,
                       preferred_element_type=F32) / np.sqrt(dh)
        cm = (start + np.arange(C))[:, None] >= np.arange(end)[None]
        s = jnp.where(jnp.asarray(cm)[None, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(va.dtype)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", pr, va,
                       preferred_element_type=F32)
        o = o.reshape(B, hq, C, dh).astype(q.dtype)

    if model_axis is not None:
        o = _gather_heads(o, model_axis)
    o = o.transpose(0, 2, 1, 3).reshape(B, C, hq_full * dh)
    x = x + o @ pm["wo"]
    if "ffn" in p:
        if cfg.layer_pattern[layer % cfg.period].moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    new_c = {"k": kc, "v": vc}
    if ks is not None:
        new_c["ks"], new_c["vs"] = ks, vs
    return x, new_c


def prefill_chunk(params, cfg: M.ModelConfig, cache, tokens, page_tables,
                  *, start: int, last_index, bucket_len: int,
                  write_tables=None, model_axis=None):
    """Prefill ONE chunk of a prompt into the paged cache.

    tokens (B, C) int32 — chunk token window covering positions
    [start, start+C); page_tables (B, max_pages) int32; `start` static and
    page-aligned; `last_index` (B,) int32 — GLOBAL index of the last real
    prompt token (logits are gathered at `clip(last_index - start, 0, C-1)`
    and are only meaningful for the chunk that contains it); `bucket_len`
    static — the padded length one-shot prefill would use, which fixes the
    per-layer BigBird-vs-full graph decision so chunked and one-shot
    prefill build identical caches; `write_tables` — optional write-side
    view of the page tables (blocks redirected to the dump page are
    computed but not persisted — the Engine uses this to keep
    prefix-SHARED pages write-free).

    Attention-only causal configs (recurrent layers chunk through their
    state sequentially and keep the one-shot admit path).  `model_axis`
    (inside shard_map): tensor-parallel head slicing, same contract as
    `decode_step`.
    Returns (logits (B, V) f32, cache)."""
    assert all(ls.kind == "attn" for ls in cfg.layer_pattern), \
        "chunked prefill supports attention-only configs"
    assert cfg.kind != "encdec", "chunked prefill is decoder-only"
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    stack = params["layers"]
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, xs):
            pslice, cslice = xs
            new_c = {}
            for i, ls in enumerate(pattern):
                x, nc = _chunk_attn_layer(
                    pslice[f"p{i}"], cslice[f"p{i}"], x, cfg,
                    cfg.attn_spec(ls), i, page_tables, start, bucket_len,
                    write_tables, model_axis)
                new_c[f"p{i}"] = nc
            return x, new_c
        x, new_cache = jax.lax.scan(body, x, (stack, cache))
    else:
        new_cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            x, nc = _chunk_attn_layer(
                stack[f"layer{i}"], cache[f"layer{i}"], x, cfg,
                cfg.attn_spec(ls), i, page_tables, start, bucket_len,
                write_tables, model_axis)
            new_cache[f"layer{i}"] = nc
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    C = x.shape[1]
    li = jnp.clip(jnp.asarray(last_index, jnp.int32) - start, 0, C - 1)
    h_last = jnp.take_along_axis(x, li[:, None, None], axis=1)[:, 0]
    logits = (h_last @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, new_cache


# --------------------------------------------------------------------------
# ragged multi-prompt prefill: one batched forward over chunks of several
# co-admitted prompts, each row at its own (traced) chunk offset
# --------------------------------------------------------------------------

def _ragged_attn_layer(p, c, x, cfg: M.ModelConfig, spec: AttentionSpec,
                       layer, page_tables, starts, bucket_len: int,
                       write_tables=None):
    """One attention layer of a RAGGED prefill chunk: every batch row
    covers positions [starts[i], starts[i]+C) of its OWN prompt, written
    and read through its own page-table row.

    This is `_chunk_attn_layer` with the chunk offset lifted from a static
    compile-time constant to a traced per-row vector (the addressing
    discipline of `_verify_attn_layer`): the chunk's KV blocks scatter
    through `take_along_axis(wt, starts//b + arange(nc))`, and the pattern
    rows/causal masks are gathered at traced block indices instead of
    sliced host-side.  Per row the gathered operands, einsum contractions
    and mask values are exactly the static chunk's — rows are independent,
    so the ragged batch is bit-identical to running each row's chunk alone
    (the chunked == one-shot contract extends to the ragged path).

    Two caller guarantees keep this exact:
      * the pattern fits the bucket for EVERY layer (no full-attention
        fallback — its dense read length would depend on the row's start);
      * every row's start is >= g*b (global *query* rows attend densely
        over a start-dependent prefix; the Engine routes chunks touching
        them to the static-offset path instead)."""
    assert spec.causal, "ragged prefill is causal-only (decoder LM serving)"
    B, C, _ = x.shape
    pm = p["mix"]
    h = L.rms_norm(pm["norm"], x, cfg.norm_eps)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    positions = starts[:, None] + jnp.arange(C)           # (B, C)
    q = (h @ pm["wq"]).reshape(B, C, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ pm["wk"]).reshape(B, C, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ pm["wv"]).reshape(B, C, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)

    b = c["k"].shape[-2]                       # physical page size
    assert C % b == 0, (C, b)
    nc = C // b
    max_pages = page_tables.shape[1]
    assert nc <= max_pages, "chunk longer than the logical cache"
    grp = hq // hkv
    # scatter this chunk's KV blocks through each row's (write) table; the
    # row's blocks are starts[i]//b + [0, nc) — in-bounds by the caller's
    # start + C <= S_log guarantee (idle rows ride at starts = 0)
    wt = page_tables if write_tables is None else write_tables
    qb = starts[:, None] // b + jnp.arange(nc)            # (B, nc)
    phys_w = jnp.take_along_axis(wt, qb, axis=1)          # (B, nc)
    as_blocks = lambda t: t.reshape(B, hkv, nc, b, dh).transpose(0, 2, 1, 3, 4)
    upd = {**_scatter_pages(c, "k", phys_w, as_blocks(k)),
           **_scatter_pages(c, "v", phys_w, as_blocks(v))}
    kc, vc = upd["k"], upd["v"]
    ks, vs = upd.get("ks"), upd.get("vs")

    # the static chunk's fallback rule must resolve to the pattern path:
    # a full-attention layer reads a start-dependent dense prefix, which
    # cannot batch across rows at different offsets
    bb = spec.bigbird_config(bucket_len)
    nbk = bucket_len // b if bucket_len % b == 0 else -1
    assert patterns.fits(bb, nbk), \
        "ragged prefill requires the pattern to fit the prompt bucket"

    if spec.impl == "pallas":
        from repro.kernels import ops                      # lazy import
        o = ops.bigbird_ragged_prefill_attn(q, kc, vc, page_tables, starts,
                                            bb, layer=layer,
                                            k_scale=ks, v_scale=vs)
    else:
        S_log = max_pages * b
        pat = patterns.build_pattern(bb, S_log, layer=layer)
        idx = jnp.asarray(pat.key_blocks)                 # (nb, Ls)
        msk = jnp.asarray(pat.key_mask)
        rows = idx[qb]                                    # (B, nc, Ls)
        rmsk = msk[qb]
        Ls = rows.shape[-1]
        kg = _paged_gather(kc, page_tables, rows.reshape(B, nc * Ls), ks) \
            .reshape(B, hkv, nc, Ls * b, dh)
        vg = _paged_gather(vc, page_tables, rows.reshape(B, nc * Ls), vs) \
            .reshape(B, hkv, nc, Ls * b, dh)
        flat = (rows[..., None] * b + jnp.arange(b)).reshape(B, nc, Ls * b)
        qpos = positions.reshape(B, nc, b)
        valid = (jnp.repeat(rmsk, b, axis=-1)[:, :, None, :]
                 & (flat[:, :, None, :] <= qpos[..., None]))  # (B,nc,b,Ls*b)
        qf = q.reshape(B, hkv, grp, nc, b, dh)
        s = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qf, kg,
                       preferred_element_type=F32) / np.sqrt(dh)
        s = jnp.where(valid[:, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
        o = jnp.einsum("bhgnqk,bhnkd->bhgnqd", pr, vg,
                       preferred_element_type=F32)
        o = o.reshape(B, hq, C, dh).astype(q.dtype)

    o = o.transpose(0, 2, 1, 3).reshape(B, C, hq * dh)
    x = x + o @ pm["wo"]
    if "ffn" in p:
        if cfg.layer_pattern[layer % cfg.period].moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    new_c = {"k": kc, "v": vc}
    if ks is not None:
        new_c["ks"], new_c["vs"] = ks, vs
    return x, new_c


def prefill_ragged(params, cfg: M.ModelConfig, cache, tokens, page_tables,
                   *, starts, last_index, bucket_len: int,
                   write_tables=None):
    """Prefill one chunk of SEVERAL prompts in one batched paged forward.

    tokens (B, C) int32 — row i holds its prompt's token window covering
    positions [starts[i], starts[i]+C); starts (B,) int32 TRACED per-row
    chunk offsets (page-aligned; one executable serves every offset mix);
    page_tables / write_tables as in `prefill_chunk`; last_index (B,) int32
    — global index of each row's last real prompt token (logits gathered at
    `clip(last_index - starts, 0, C-1)`, meaningful only for rows whose
    chunk contains it); `bucket_len` static — a REPRESENTATIVE one-shot
    bucket: rows of different buckets may share one ragged batch whenever
    their per-layer graph decisions agree (the Engine groups by graph key,
    which the bucket only enters through).

    Caller contract (serve/engine.py enforces it):
      * the BigBird pattern fits `bucket_len` for every layer, and
      * every live row's start is >= num_global_blocks * b, and
      * starts[i] + C <= max_pages * page_size for every row
    — the three conditions under which a chunk's attention is a pure
    pattern read, independent of the row's offset, making the ragged batch
    bit-identical per row to the static `prefill_chunk` path (and hence to
    one-shot prefill).  Idle/padding rows ride at starts = 0 with dump-page
    tables; their math is discarded.

    Returns (logits (B, V) f32, cache)."""
    assert all(ls.kind == "attn" for ls in cfg.layer_pattern), \
        "ragged prefill supports attention-only configs"
    assert cfg.kind != "encdec", "ragged prefill is decoder-only"
    starts = jnp.asarray(starts, jnp.int32)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    stack = params["layers"]
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, xs):
            pslice, cslice = xs
            new_c = {}
            for i, ls in enumerate(pattern):
                x, nc = _ragged_attn_layer(
                    pslice[f"p{i}"], cslice[f"p{i}"], x, cfg,
                    cfg.attn_spec(ls), i, page_tables, starts, bucket_len,
                    write_tables)
                new_c[f"p{i}"] = nc
            return x, new_c
        x, new_cache = jax.lax.scan(body, x, (stack, cache))
    else:
        new_cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            x, nc = _ragged_attn_layer(
                stack[f"layer{i}"], cache[f"layer{i}"], x, cfg,
                cfg.attn_spec(ls), i, page_tables, starts, bucket_len,
                write_tables)
            new_cache[f"layer{i}"] = nc
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    C = x.shape[1]
    li = jnp.clip(jnp.asarray(last_index, jnp.int32) - starts, 0, C - 1)
    h_last = jnp.take_along_axis(x, li[:, None, None], axis=1)[:, 0]
    logits = (h_last @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, new_cache


# --------------------------------------------------------------------------
# speculative verify: score k+1 candidate tokens in one paged forward
# --------------------------------------------------------------------------

def _verify_attn_layer(p, c, x, cfg: M.ModelConfig, spec: AttentionSpec,
                       layer, pos, n_valid, page_tables, model_axis=None):
    """One attention layer of a verify window: T candidate tokens per slot
    at positions [pos, pos+T), written and read through the page table.

    Query t reads exactly the keys <= pos+t its pattern row admits — the
    same gather, mask, and contraction order `decode_step` runs for a
    single token at pos+t — so the verify logits are bit-identical to T
    sequential decode steps over the accepted prefix (later candidates'
    K/V are masked and contribute exactly 0; see DESIGN.md §Speculative
    decoding).  Writes for candidates past `n_valid` (per-slot draft
    length) or past the logical cache end are dropped (out-of-range
    scatter with mode="drop") so padding can never alias a live page."""
    assert spec.causal, "verify is causal-only (decoder LM serving)"
    B, T, _ = x.shape
    pm = p["mix"]
    h = L.rms_norm(pm["norm"], x, cfg.norm_eps)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    positions = pos[:, None] + jnp.arange(T)              # (B, T)
    q = (h @ pm["wq"]).reshape(B, T, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ pm["wk"]).reshape(B, T, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ pm["wv"]).reshape(B, T, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    hq_full = hq
    if model_axis is not None:
        q, k, v = _local_heads(q, k, v, c["k"], model_axis)
        hq, hkv = q.shape[1], k.shape[1]
    grp = hq // hkv

    b = c["k"].shape[-2]
    P = c["k"].shape[0]                    # (shard-local) physical pages
    max_pages = page_tables.shape[1]
    S = max_pages * b                      # logical cache length
    # write the window's K/V at pos+t through the table; invalid tokens
    # (t > n_valid, or positions past the cache end) are dropped — a
    # clamped table lookup must never redirect them onto a live page
    blk = jnp.clip(positions // b, 0, max_pages - 1)
    pg = jnp.take_along_axis(page_tables, blk, axis=1)        # (B, T)
    ok = (jnp.arange(T)[None] <= n_valid[:, None]) & (positions < S)
    pg = jnp.where(ok, pg, P)              # out of bounds -> dropped
    off = positions % b
    if "ks" in c:
        # int8 pages: candidates land one by one (T is small and static) so
        # several candidates sharing a page requantize it cumulatively —
        # the same RMW discipline sequential decode applies
        kc, ks = c["k"], c["ks"]
        vc, vs = c["v"], c["vs"]
        for t in range(T):
            kc, ks = _quant_token_write(kc, ks, k[:, :, t], pg[:, t],
                                        off[:, t], drop=True)
            vc, vs = _quant_token_write(vc, vs, v[:, :, t], pg[:, t],
                                        off[:, t], drop=True)
    else:
        ks = vs = None
        kc = c["k"].at[pg, :, off].set(
            k.transpose(0, 2, 1, 3).astype(c["k"].dtype), mode="drop")
        vc = c["v"].at[pg, :, off].set(
            v.transpose(0, 2, 1, 3).astype(c["v"].dtype), mode="drop")

    # the same bigbird-vs-full decision decode_step makes at the logical
    # cache length (the verify == sequential-decode graph key)
    use_bb = spec.kind in ("bigbird", "window")
    if use_bb:
        bb = spec.bigbird_config(S)
        nb = S // bb.block_size if S % bb.block_size == 0 else -1
        if not patterns.fits(bb, nb):
            use_bb = False

    if use_bb:
        pat = patterns.build_pattern(bb, S, layer=layer)
        idx = jnp.asarray(pat.key_blocks)              # (nb, Ls)
        msk = jnp.asarray(pat.key_mask)
        jq = positions // b                            # (B, T), OOB clamps
        row_idx, row_msk = idx[jq], msk[jq]            # (B, T, Ls)
        Ls = row_idx.shape[-1]
        kg = _paged_gather(kc, page_tables, row_idx.reshape(B, T * Ls), ks) \
            .reshape(B, hkv, T, Ls * b, dh)
        vg = _paged_gather(vc, page_tables, row_idx.reshape(B, T * Ls), vs) \
            .reshape(B, hkv, T, Ls * b, dh)
        flat = (row_idx[..., None] * b
                + jnp.arange(b)).reshape(B, T, Ls * b)
        valid = (jnp.repeat(row_msk, b, axis=-1)
                 & (flat <= positions[:, :, None]))    # (B, T, Ls*b)
        qf = q.reshape(B, hkv, grp, T, dh)
        s = jnp.einsum("bhgtd,bhtkd->bhgtk", qf, kg,
                       preferred_element_type=F32) / np.sqrt(dh)
        s = jnp.where(valid[:, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
        o = jnp.einsum("bhgtk,bhtkd->bhgtd", pr, vg,
                       preferred_element_type=F32)
    else:
        blocks = jnp.broadcast_to(
            jnp.arange(max_pages, dtype=jnp.int32)[None], (B, max_pages))
        ka = _paged_gather(kc, page_tables, blocks, ks)    # (B, H, S, dh)
        va = _paged_gather(vc, page_tables, blocks, vs)
        qf = q.reshape(B, hkv, grp, T, dh)
        s = jnp.einsum("bhgtd,bhsd->bhgts", qf, ka,
                       preferred_element_type=F32) / np.sqrt(dh)
        cm = jnp.arange(S)[None, None] <= positions[:, :, None]  # (B, T, S)
        s = jnp.where(cm[:, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(va.dtype)
        o = jnp.einsum("bhgts,bhsd->bhgtd", pr, va,
                       preferred_element_type=F32)
    o = o.reshape(B, hq, T, dh).astype(q.dtype)
    if model_axis is not None:
        o = _gather_heads(o, model_axis)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, hq_full * dh)
    x = x + o @ pm["wo"]
    new_c = dict(c)
    new_c["k"], new_c["v"] = kc, vc
    if ks is not None:
        new_c["ks"], new_c["vs"] = ks, vs
    if "ffn" in p:
        if cfg.layer_pattern[layer % cfg.period].moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, new_c


def verify_step(params, cfg: M.ModelConfig, cache, tokens, pos, n_valid,
                page_tables, model_axis=None):
    """Score a speculative window in ONE paged forward.

    tokens (B, T) int32 — column 0 is the slot's last sampled (not yet
    written) token, columns 1..n_valid[i] are draft candidates, the rest
    padding; pos (B,) int32 — the position column 0 writes at (the slot's
    next write position, exactly `decode_step`'s contract); n_valid (B,)
    int32 — per-slot draft length (window writes past it are dropped).

    Returns (logits (B, T, V) f32, cache): `logits[:, t]` is the target
    model's next-token distribution AFTER the candidate at pos+t — the
    distribution sequential decode would have produced at that step, bit
    for bit.  Acceptance (greedy exact-match / residual rejection
    sampling) is the caller's job (serve/spec.py); `decode_step` is the
    T == 1 special case of this path.  Paged, attention-only, causal-LM
    only — the same envelope as chunked prefill."""
    assert all(ls.kind == "attn" for ls in cfg.layer_pattern), \
        "speculative verify supports attention-only configs"
    assert cfg.kind != "encdec", "speculative verify is decoder-only"
    pos = jnp.asarray(pos, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    stack = params["layers"]
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, xs):
            pslice, cslice = xs
            new_c = {}
            for i, ls in enumerate(pattern):
                x, nc = _verify_attn_layer(
                    pslice[f"p{i}"], cslice[f"p{i}"], x, cfg,
                    cfg.attn_spec(ls), i, pos, n_valid, page_tables,
                    model_axis)
                new_c[f"p{i}"] = nc
            return x, new_c
        x, new_cache = jax.lax.scan(body, x, (stack, cache))
    else:
        new_cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            x, nc = _verify_attn_layer(
                stack[f"layer{i}"], cache[f"layer{i}"], x, cfg,
                cfg.attn_spec(ls), i, pos, n_valid, page_tables, model_axis)
            new_cache[f"layer{i}"] = nc
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    logits = (x @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, new_cache


# --------------------------------------------------------------------------
# tree verification (speculative token trees; DESIGN.md §Speculative decoding)
# --------------------------------------------------------------------------

def _verify_tree_attn_layer(p, c, x, cfg: M.ModelConfig, spec: AttentionSpec,
                            layer, pos, page_tables, depths, anc,
                            model_axis=None):
    """One attention layer of a TREE verify window: T tree nodes per slot,
    node t at logical position pos + depths[t], its within-window key set
    being exactly its own root-to-node ancestor chain (`anc[t, j]` = the
    ancestor of node t at depth j; node 0 is the root, the slot's pending
    last token).

    Unlike the linear window, sibling nodes share a logical position, so
    the tree pass never writes the cache: the pattern-row gather still runs
    against the paged store, and gathered slots that fall INSIDE the window
    (pos <= flat <= pos + depth(t)) are substituted per query with the
    fresh K/V of t's ancestor at that depth — the cache rows sequential
    decode would have held had t's path been taken, value for value, in the
    same gathered slot, so the contraction is the linear verify's with
    different operand values only.  The layer returns its window K/V; the
    accepted root-to-leaf path is persisted afterwards by `commit_window`
    (the caller knows the path only after acceptance)."""
    assert spec.causal, "verify is causal-only (decoder LM serving)"
    B, T, _ = x.shape
    pm = p["mix"]
    h = L.rms_norm(pm["norm"], x, cfg.norm_eps)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    depths = jnp.asarray(depths, jnp.int32)               # (T,)
    anc = jnp.asarray(anc, jnp.int32)                     # (T, Dmax + 1)
    positions = pos[:, None] + depths[None, :]            # (B, T)
    q = (h @ pm["wq"]).reshape(B, T, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ pm["wk"]).reshape(B, T, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ pm["wv"]).reshape(B, T, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    hq_full = hq
    if model_axis is not None:
        q, k, v = _local_heads(q, k, v, c["k"], model_axis)
        hq, hkv = q.shape[1], k.shape[1]
    # shard-local window K/V handed to commit_window (heads match c["k"])
    wk, wv = k, v
    grp = hq // hkv

    b = c["k"].shape[-2]
    max_pages = page_tables.shape[1]
    S = max_pages * b
    ks = c.get("ks")
    vs = c.get("vs")

    # the same bigbird-vs-full decision decode_step makes at the logical
    # cache length — tree and sequential decode must build the same graph
    use_bb = spec.kind in ("bigbird", "window")
    if use_bb:
        bb = spec.bigbird_config(S)
        nb = S // bb.block_size if S % bb.block_size == 0 else -1
        if not patterns.fits(bb, nb):
            use_bb = False

    if use_bb:
        pat = patterns.build_pattern(bb, S, layer=layer)
        idx = jnp.asarray(pat.key_blocks)              # (nb, Ls)
        msk = jnp.asarray(pat.key_mask)
        jq = positions // b                            # (B, T), OOB clamps
        row_idx, row_msk = idx[jq], msk[jq]            # (B, T, Ls)
    else:
        # full fallback: every logical block is "the pattern row" (the
        # dense gather order sequential decode uses), per query — costs
        # T x the dense read, acceptable at the small S this branch serves
        row_idx = jnp.broadcast_to(
            jnp.arange(max_pages, dtype=jnp.int32)[None, None],
            (B, T, max_pages))
        row_msk = jnp.ones((B, T, max_pages), bool)
    Ls = row_idx.shape[-1]
    kg = _paged_gather(c["k"], page_tables, row_idx.reshape(B, T * Ls), ks) \
        .reshape(B, hkv, T, Ls * b, dh)
    vg = _paged_gather(c["v"], page_tables, row_idx.reshape(B, T * Ls), vs) \
        .reshape(B, hkv, T, Ls * b, dh)
    flat = (row_idx[..., None] * b
            + jnp.arange(b)).reshape(B, T, Ls * b)
    # ancestor substitution: a gathered slot at in-window depth j holds,
    # for query t, the fresh K/V of t's ancestor at depth j (the linear
    # window is the chain special case anc[t, j] = j, where the cache rows
    # the gather returns are already exactly these values)
    rel = flat - pos[:, None, None]                       # (B, T, Ls*b)
    inwin = (rel >= 0) & (rel <= depths[None, :, None])
    src = anc[jnp.arange(T)[None, :, None],
              jnp.clip(rel, 0, anc.shape[1] - 1)]         # (B, T, Ls*b)
    bidx = jnp.arange(B)[:, None, None]
    ksub = k.astype(kg.dtype).transpose(0, 2, 1, 3)[bidx, src] \
        .transpose(0, 3, 1, 2, 4)                         # (B, hkv, T, K, dh)
    vsub = v.astype(vg.dtype).transpose(0, 2, 1, 3)[bidx, src] \
        .transpose(0, 3, 1, 2, 4)
    sel = inwin[:, None, :, :, None]
    kg = jnp.where(sel, ksub, kg)
    vg = jnp.where(sel, vsub, vg)
    valid = (jnp.repeat(row_msk, b, axis=-1)
             & (flat <= positions[:, :, None]))           # (B, T, Ls*b)
    qf = q.reshape(B, hkv, grp, T, dh)
    s = jnp.einsum("bhgtd,bhtkd->bhgtk", qf, kg,
                   preferred_element_type=F32) / np.sqrt(dh)
    s = jnp.where(valid[:, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
    o = jnp.einsum("bhgtk,bhtkd->bhgtd", pr, vg,
                   preferred_element_type=F32)
    o = o.reshape(B, hq, T, dh).astype(q.dtype)
    if model_axis is not None:
        o = _gather_heads(o, model_axis)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, hq_full * dh)
    x = x + o @ pm["wo"]
    if "ffn" in p:
        if cfg.layer_pattern[layer % cfg.period].moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, {"k": wk, "v": wv}


def verify_tree_step(params, cfg: M.ModelConfig, cache, tokens, pos,
                     page_tables, depths, anc, model_axis=None):
    """Score a speculative token TREE in ONE paged forward.

    tokens (B, T) int32 — node 0 is the slot's pending last token (the
    tree root), nodes 1.. are draft candidates; `depths` (T,) int and
    `anc` (T, Dmax+1) int are the STATIC tree topology shared by every
    slot (anc[t, j] = t's ancestor node at depth j, anc[t, depths[t]] = t).
    pos (B,) int32 — the root's write position, `decode_step`'s contract.

    Returns (logits (B, T, V) f32, window_kv): `logits[:, t]` is the
    target's next-token distribution after node t GIVEN t's root-to-node
    path — for every node, the distribution sequential decode would
    produce after emitting that path.  The cache is NOT written (siblings
    share logical positions); `window_kv` carries each layer's fresh
    window K/V so `commit_window` can persist the accepted path once the
    caller has walked the tree (serve/spec.py `accept_tree`)."""
    assert all(ls.kind == "attn" for ls in cfg.layer_pattern), \
        "speculative verify supports attention-only configs"
    assert cfg.kind != "encdec", "speculative verify is decoder-only"
    pos = jnp.asarray(pos, jnp.int32)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    stack = params["layers"]
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, xs):
            pslice, cslice = xs
            wkv = {}
            for i, ls in enumerate(pattern):
                x, w = _verify_tree_attn_layer(
                    pslice[f"p{i}"], cslice[f"p{i}"], x, cfg,
                    cfg.attn_spec(ls), i, pos, page_tables, depths, anc,
                    model_axis)
                wkv[f"p{i}"] = w
            return x, wkv
        x, window_kv = jax.lax.scan(body, x, (stack, cache))
    else:
        window_kv = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            x, w = _verify_tree_attn_layer(
                stack[f"layer{i}"], cache[f"layer{i}"], x, cfg,
                cfg.attn_spec(ls), i, pos, page_tables, depths, anc,
                model_axis)
            window_kv[f"layer{i}"] = w
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    logits = (x @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, window_kv


def _commit_layer(c, w, page_tables, pos, path, cnt):
    """Persist one layer's accepted path: token j of `path` (a window node
    index) writes its window K/V at logical position pos + j, for
    j < cnt (out-of-range / surplus writes scatter with mode="drop")."""
    B, J = path.shape
    b = c["k"].shape[-2]
    P = c["k"].shape[0]
    max_pages = page_tables.shape[1]
    S = max_pages * b
    positions = pos[:, None] + jnp.arange(J)              # (B, J)
    blk = jnp.clip(positions // b, 0, max_pages - 1)
    pg = jnp.take_along_axis(page_tables, blk, axis=1)
    ok = (jnp.arange(J)[None] < cnt[:, None]) & (positions < S)
    pg = jnp.where(ok, pg, P)
    off = positions % b
    sel = path[:, :, None, None]
    kw = jnp.take_along_axis(w["k"].transpose(0, 2, 1, 3), sel, 1)  # (B,J,H,dh)
    vw = jnp.take_along_axis(w["v"].transpose(0, 2, 1, 3), sel, 1)
    new_c = dict(c)
    if "ks" in c:
        # int8 pages: the accepted tokens land one by one, the exact RMW
        # monotone-scale discipline sequential decode applies — and unlike
        # the linear window, no rejected garbage ever inflates a scale
        kc, ks, vc, vs = c["k"], c["ks"], c["v"], c["vs"]
        for j in range(J):
            kc, ks = _quant_token_write(kc, ks, kw[:, j], pg[:, j],
                                        off[:, j], drop=True)
            vc, vs = _quant_token_write(vc, vs, vw[:, j], pg[:, j],
                                        off[:, j], drop=True)
        new_c.update(k=kc, ks=ks, v=vc, vs=vs)
    else:
        new_c["k"] = c["k"].at[pg, :, off].set(
            kw.astype(c["k"].dtype), mode="drop")
        new_c["v"] = c["v"].at[pg, :, off].set(
            vw.astype(c["v"].dtype), mode="drop")
    return new_c


def commit_window(cfg: M.ModelConfig, cache, window_kv, page_tables, pos,
                  path, cnt):
    """Write a tree-verify round's accepted root-to-leaf path into the
    paged cache.  path (B, J) int32 — window node indices, entry 0 the
    root; cnt (B,) int32 — tokens to persist (the root plus the accepted
    candidates; the corrected/bonus token is sampled, never written).
    Positions pos..pos+cnt-1 end up holding exactly the K/V sequential
    decode would have written there (`_verify_tree_attn_layer` computes
    them from the same path-conditioned hidden states)."""
    pos = jnp.asarray(pos, jnp.int32)
    path = jnp.asarray(path, jnp.int32)
    cnt = jnp.asarray(cnt, jnp.int32)
    stacked = not all(k.startswith("layer") for k in cache)
    if stacked:
        def body(_, xs):
            cslice, wslice = xs
            return None, {key: _commit_layer(cslice[key], wslice[key],
                                             page_tables, pos, path, cnt)
                          for key in cslice}
        _, new_cache = jax.lax.scan(body, None, (cache, window_kv))
        return new_cache
    return {key: _commit_layer(cache[key], window_kv[key], page_tables,
                               pos, path, cnt)
            for key in cache}


# --------------------------------------------------------------------------
# prefill (forward pass that also fills the caches)
# --------------------------------------------------------------------------

def _prefill_layer(p, x, cfg, ls, layer, positions, max_len, enc_kv=None):
    B, S, _ = x.shape
    if ls.kind == "attn":
        out, (k, v) = L.attn_block(
            p["mix"], x, cfg.attn_spec(ls), cfg.num_heads, cfg.num_kv_heads,
            cfg.hd, positions=positions, theta=cfg.rope_theta, layer=layer,
            eps=cfg.norm_eps, return_kv=True)
        pad = max_len - S
        c = {"k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cfg.dtype),
             "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cfg.dtype)}
        if enc_kv is not None:
            out = L.attn_block(p["cross"], out,
                               AttentionSpec(kind="full", causal=False),
                               cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                               positions=None, eps=cfg.norm_eps,
                               kv_override=enc_kv)
            c["ck"], c["cv"] = (enc_kv[0].astype(cfg.dtype),
                                enc_kv[1].astype(cfg.dtype))
        x = out
    elif ls.kind == "mamba":
        dt_rank = max(cfg.d_model // 16, 8)
        x, (h_last, tail) = L.mamba_block(
            p["mix"], x, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_conv,
            dt_rank=dt_rank, eps=cfg.norm_eps, return_state=True)
        c = {"h": h_last, "conv": tail.astype(cfg.dtype)}
    elif ls.kind == "rwkv":
        nh = cfg.d_model // cfg.rwkv_head_dim
        x, (tm, s, cm) = L.rwkv_block(p["mix"], x, nh, cfg.rwkv_head_dim,
                                      eps=cfg.norm_eps, return_state=True)
        return x, {"tm": tm.astype(cfg.dtype), "s": s, "cm": cm.astype(cfg.dtype)}
    else:
        raise ValueError(ls.kind)
    if "ffn" in p:
        if ls.moe:
            x, _ = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, c


def prefill(params, cfg: M.ModelConfig, batch, max_len, last_index=None):
    """Run the prompt through the model, returning (last-token logits, cache).

    For encdec, batch must contain "frames" (encoder input) and "tokens"
    (decoder prompt); cache includes per-layer cross K/V.

    `last_index` (B,) int32: per-row index of the last *real* prompt token.
    The Engine right-pads prompts to a bucketed length before prefill;
    under causal attention the padded tail cannot influence positions
    <= last_index, so gathering logits there (instead of at -1) makes
    bucketed prefill exact.  None keeps the original "last column" output.
    """
    enc_h = None
    if cfg.kind == "encdec":
        enc_h, _ = M._encoder_hidden(params, cfg, batch["frames"])
        stack = params["decoder"]
    else:
        stack = params["layers"]
    x = M._embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    pattern = cfg.layer_pattern
    scanned = cfg.scan_layers and cfg.repeats > 1 and \
        not all(k.startswith("layer") for k in stack)

    if scanned:
        def body(x, pslice):
            cs = {}
            for i, ls in enumerate(pattern):
                enc_kv = (L.cross_kv(pslice[f"p{i}"]["cross"], enc_h,
                                     cfg.num_kv_heads, cfg.hd)
                          if enc_h is not None else None)
                x, c = _prefill_layer(pslice[f"p{i}"], x, cfg, ls, i,
                                      positions, max_len, enc_kv)
                cs[f"p{i}"] = c
            return x, cs
        x, cache = jax.lax.scan(body, x, stack)
    else:
        cache = {}
        for i in range(cfg.num_layers):
            ls = pattern[i % len(pattern)]
            p = stack[f"layer{i}"]
            enc_kv = (L.cross_kv(p["cross"], enc_h, cfg.num_kv_heads, cfg.hd)
                      if enc_h is not None else None)
            x, c = _prefill_layer(p, x, cfg, ls, i, positions, max_len, enc_kv)
            cache[f"layer{i}"] = c
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = M._unembed_weight(params, cfg)
    if last_index is None:
        h_last = x[:, -1]
    else:
        idx = jnp.asarray(last_index, jnp.int32)[:, None, None]
        h_last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
    logits = (h_last @ w_out).astype(F32)[..., :cfg.vocab_size]
    return logits, cache
