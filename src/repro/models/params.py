"""Minimal parameter-spec system (framework-native, no flax).

A model is described by a *spec tree*: nested dicts whose leaves are `P`
(shape + logical axes + init).  From one spec tree we derive
  * materialized params     (init_params — smoke tests / real training),
  * ShapeDtypeStruct stand-ins (abstract_params — the dry-run, no allocation),
  * PartitionSpecs          (dist.sharding.partition_tree).

Logical axis vocabulary (mapped to mesh axes by dist/sharding.py rules):
  vocab, embed, heads, kv_heads, head_dim, mlp, experts, layers, conv, state,
  None (never sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["P", "init_params", "abstract_params", "map_leaves", "leaf_count",
           "param_count"]


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple
    axes: tuple                      # logical axis name (or None) per dim
    init: str = "normal"             # normal | zeros | ones | scaled | small
    dtype: Optional[Any] = None      # None -> model default
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x):
    return isinstance(x, P)


def map_leaves(fn, tree):
    if _is_leaf(tree):
        return fn(tree)
    return {k: map_leaves(fn, v) for k, v in tree.items()}


def leaf_count(tree) -> int:
    if _is_leaf(tree):
        return 1
    return sum(leaf_count(v) for v in tree.values())


def param_count(tree) -> int:
    if _is_leaf(tree):
        return int(np.prod(tree.shape))
    return sum(param_count(v) for v in tree.values())


def _init_leaf(p: P, key, default_dtype):
    dtype = p.dtype or default_dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":             # GPT-2 style
        return (0.02 * p.scale * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "scaled":             # 1/sqrt(fan_in), fan_in = dim -2
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale / np.sqrt(fan_in)
        return (std * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "small":
        return (1e-3 * p.scale * jax.random.normal(key, p.shape)).astype(dtype)
    raise ValueError(p.init)


def init_params(spec_tree, key, default_dtype=jnp.float32):
    """Materialize a params pytree from a spec tree (deterministic in key)."""
    flat = []

    def collect(tree, path):
        if _is_leaf(tree):
            flat.append((path, tree))
        else:
            for k in sorted(tree):
                collect(tree[k], path + (k,))

    collect(spec_tree, ())
    keys = jax.random.split(key, max(len(flat), 1))

    out: dict = {}
    for (path, p), k in zip(flat, keys):
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = _init_leaf(p, k, default_dtype)
    return out


def abstract_params(spec_tree, default_dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no device allocation)."""
    return map_leaves(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or default_dtype),
        spec_tree)
