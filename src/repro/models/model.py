"""Unified model assembly: decoder LMs (dense / MoE / SSM / hybrid) and
encoder-decoder models, with scan-over-layer-groups, remat, chunked CE loss,
and decode (serving) paths.

A model is a `ModelConfig` + pure functions.  Layer heterogeneity (gemma 5:1
local:global, jamba 1:7 attn:mamba, MoE interleave) is expressed by
`layer_pattern`: a period of LayerSpecs that is scanned `num_layers/period`
times (params stacked on a leading "layers" axis).

Note on random-attention seeds under scan: random blocks vary per *position
in the period* but are shared across repeats (a static-pattern requirement of
the scanned representation; deviation from the paper noted in DESIGN.md).
With scan_layers=False (small/smoke configs) every layer gets its own blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionSpec
from repro.models import layers as L
from repro.models.params import P, abstract_params, init_params, map_leaves

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"                     # attn | mamba | rwkv
    attn: Optional[AttentionSpec] = None   # None -> model default
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    d_model: int = 512
    num_layers: int = 4
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0                      # 0 -> d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    layer_pattern: tuple = (LayerSpec(),)
    attn: AttentionSpec = AttentionSpec(kind="full", causal=True)
    moe: Optional[L.MoEConfig] = None
    kind: str = "lm"                       # lm | encdec
    enc_layers: int = 0
    enc_attn: Optional[AttentionSpec] = None
    dec_len: int = 448                     # encdec decoder length
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    remat: str = "full"                    # none | full | dots
    scan_layers: bool = True
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    rwkv_head_dim: int = 64
    frontend: Optional[str] = None         # None | patch | audio
    frontend_len: int = 256
    max_seq: int = 4096
    loss_chunk: int = 512
    aux_loss_weight: float = 0.01
    vocab_pad: int = 1       # pad vocab to a multiple (shardability, §Perf)

    @property
    def padded_vocab(self):
        return -(-self.vocab_size // self.vocab_pad) * self.vocab_pad

    @property
    def hd(self):
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self):
        return len(self.layer_pattern)

    @property
    def repeats(self):
        assert self.num_layers % self.period == 0
        return self.num_layers // self.period

    def attn_spec(self, ls: LayerSpec) -> AttentionSpec:
        return ls.attn if ls.attn is not None else self.attn


# --------------------------------------------------------------------------
# param spec construction
# --------------------------------------------------------------------------

def _ffn_spec(cfg: ModelConfig, ls: LayerSpec):
    if ls.moe:
        assert cfg.moe is not None
        return L.moe_spec(cfg.d_model, cfg.moe)
    return L.mlp_spec(cfg.d_model, cfg.d_ff)


def _layer_spec_tree(cfg: ModelConfig, ls: LayerSpec, cross: bool = False):
    d = cfg.d_model
    if ls.kind == "attn":
        tree = {"mix": L.attn_block_spec(d, cfg.num_heads, cfg.num_kv_heads, cfg.hd),
                "ffn": _ffn_spec(cfg, ls)}
        if cross:
            tree["cross"] = L.attn_block_spec(d, cfg.num_heads, cfg.num_kv_heads, cfg.hd)
        return tree
    if ls.kind == "mamba":
        di = cfg.mamba_expand * d
        dt_rank = max(d // 16, 8)
        return {"mix": L.mamba_spec(d, di, cfg.mamba_d_state, cfg.mamba_conv, dt_rank),
                "ffn": _ffn_spec(cfg, ls)}
    if ls.kind == "rwkv":
        nh = d // cfg.rwkv_head_dim
        return {"mix": L.rwkv_spec(d, cfg.d_ff, nh, cfg.rwkv_head_dim)}
    raise ValueError(ls.kind)


def _stack(tree, repeats):
    return map_leaves(
        lambda p: P((repeats,) + p.shape, ("layers",) + p.axes,
                    init=p.init, dtype=p.dtype, scale=p.scale), tree)


def _stack_spec(cfg: ModelConfig, pattern, repeats, cross=False):
    if repeats == 1 or not cfg.scan_layers:
        # unstacked: one subtree per layer (smoke configs)
        return {f"layer{i}": _layer_spec_tree(cfg, pattern[i % len(pattern)], cross)
                for i in range(repeats * len(pattern))}
    return {f"p{i}": _stack(_layer_spec_tree(cfg, ls, cross), repeats)
            for i, ls in enumerate(pattern)}


def param_spec(cfg: ModelConfig):
    spec = {"embed": L.embedding_spec(cfg.padded_vocab, cfg.d_model),
            "final_norm": L.rms_norm_spec(cfg.d_model)}
    if not cfg.tie_embeddings:
        spec["unembed"] = {"w": P((cfg.d_model, cfg.padded_vocab),
                                  ("embed", "vocab"), init="scaled")}
    if cfg.kind == "encdec":
        enc_pat = (LayerSpec(kind="attn", attn=cfg.enc_attn),)
        spec["encoder"] = _stack_spec(cfg, enc_pat, cfg.enc_layers)
        spec["enc_norm"] = L.rms_norm_spec(cfg.d_model)
        spec["decoder"] = _stack_spec(cfg, cfg.layer_pattern, cfg.repeats, cross=True)
    else:
        spec["layers"] = _stack_spec(cfg, cfg.layer_pattern, cfg.repeats)
    return spec


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _apply_layer(p, x, cfg: ModelConfig, ls: LayerSpec, layer_idx, positions,
                 enc_kv=None):
    aux = jnp.zeros((), F32)
    if ls.kind == "attn":
        spec = cfg.attn_spec(ls)
        x = L.attn_block(p["mix"], x, spec, cfg.num_heads, cfg.num_kv_heads,
                         cfg.hd, positions=positions, theta=cfg.rope_theta,
                         layer=layer_idx, eps=cfg.norm_eps)
        if enc_kv is not None:
            x = L.attn_block(p["cross"], x,
                             AttentionSpec(kind="full", causal=False),
                             cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                             positions=None, layer=layer_idx,
                             eps=cfg.norm_eps, kv_override=enc_kv)
    elif ls.kind == "mamba":
        dt_rank = max(cfg.d_model // 16, 8)
        x = L.mamba_block(p["mix"], x, d_state=cfg.mamba_d_state,
                          d_conv=cfg.mamba_conv, dt_rank=dt_rank,
                          eps=cfg.norm_eps)
    elif ls.kind == "rwkv":
        nh = cfg.d_model // cfg.rwkv_head_dim
        x = L.rwkv_block(p["mix"], x, nh, cfg.rwkv_head_dim, eps=cfg.norm_eps)
        return x, aux                                  # rwkv has its own ffn
    if "ffn" in p:
        if ls.moe:
            x, aux = L.moe_block(p["ffn"], x, cfg.moe, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["ffn"], x, eps=cfg.norm_eps)
    return x, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _apply_stack(stack_params, x, cfg: ModelConfig, pattern, positions,
                 enc_kv=None, cross=False):
    """Run the layer stack; returns (x, aux_sum)."""
    if not cfg.scan_layers or all(k.startswith("layer") for k in stack_params):
        aux = jnp.zeros((), F32)
        for i in range(len(stack_params)):
            ls = pattern[i % len(pattern)]
            x, a = _apply_layer(stack_params[f"layer{i}"], x, cfg, ls, i,
                                positions, enc_kv if cross else None)
            aux = aux + a
        return x, aux

    def body(carry, pslice):
        x = carry
        aux = jnp.zeros((), F32)
        for i, ls in enumerate(pattern):
            x, a = _apply_layer(pslice[f"p{i}"], x, cfg, ls, i, positions,
                                enc_kv if cross else None)
            aux = aux + a
        return x, aux

    body = _remat_wrap(body, cfg)
    x, auxs = jax.lax.scan(body, x, stack_params)
    return x, jnp.sum(auxs)


def _embed_inputs(params, cfg: ModelConfig, batch):
    from repro.dist.annotate import constrain
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    if cfg.frontend == "patch" and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(cfg.dtype)
        x = jnp.concatenate([fe, x[:, cfg.frontend_len:]], axis=1)
    return constrain(x, ("batch", None, "embed"))


def hidden_states(params, cfg: ModelConfig, batch):
    """LM trunk: embeddings -> layer stack -> final norm.  (B, S, d)."""
    if cfg.kind == "encdec":
        return _encdec_hidden(params, cfg, batch)
    x = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, aux = _apply_stack(params["layers"], x, cfg, cfg.layer_pattern, positions)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def _sinusoid(S, d, dtype):
    """Whisper-style fixed sinusoidal encoder positions (RoPE alone leaves
    encoder hidden states position-agnostic to cross-attention queries)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=F32) / half)
    ang = jnp.arange(S, dtype=F32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1)[:, :d].astype(dtype)


def _encoder_hidden(params, cfg: ModelConfig, frames):
    x = frames.astype(cfg.dtype)
    S = x.shape[1]
    # position scale matched to the content scale so neither drowns the
    # other (frame embeddings may be sigma=0.02 lookups or O(1) features)
    rms = jnp.sqrt(jnp.mean(jnp.square(x.astype(F32))) + 1e-9).astype(cfg.dtype)
    x = x + 0.5 * rms * _sinusoid(S, cfg.d_model, cfg.dtype)[None]
    pos = jnp.arange(S)
    enc_pat = (LayerSpec(kind="attn", attn=cfg.enc_attn),)
    x, aux = _apply_stack(params["encoder"], x, cfg, enc_pat, pos)
    return L.rms_norm(params["enc_norm"], x, cfg.norm_eps), aux


def _encdec_hidden(params, cfg: ModelConfig, batch):
    enc_h, aux_e = _encoder_hidden(params, cfg, batch["frames"])
    # cross K/V computed once from encoder states; shared by all dec layers?
    # no — each decoder layer has its own cross projections; computed inside.
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    pos = jnp.arange(x.shape[1])

    # cross-attention needs per-layer K/V from enc_h; pass enc_h and project
    # in-layer via kv_override machinery:
    def make_enc_kv(p):
        return L.cross_kv(p["cross"], enc_h, cfg.num_kv_heads, cfg.hd)

    if not cfg.scan_layers or all(k.startswith("layer") for k in params["decoder"]):
        aux = aux_e
        for i in range(len(params["decoder"])):
            p = params["decoder"][f"layer{i}"]
            ls = cfg.layer_pattern[i % cfg.period]
            x, a = _apply_layer(p, x, cfg, ls, i, pos, enc_kv=make_enc_kv(p))
            aux = aux + a
    else:
        def body(carry, pslice):
            x = carry
            aux = jnp.zeros((), F32)
            for i, ls in enumerate(cfg.layer_pattern):
                p = pslice[f"p{i}"]
                x, a = _apply_layer(p, x, cfg, ls, i, pos, enc_kv=make_enc_kv(p))
                aux = aux + a
            return x, aux
        body = _remat_wrap(body, cfg)
        x, auxs = jax.lax.scan(body, x, params["decoder"])
        aux = aux_e + jnp.sum(auxs)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


# --------------------------------------------------------------------------
# loss (chunked cross-entropy — never materializes (B, S, V))
# --------------------------------------------------------------------------

def _unembed_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T          # (d, V)
    return params["unembed"]["w"]


def chunked_ce_loss(h, w_out, labels, chunk, loss_mask=None, vocab_real=None):
    """h (B,S,d), w_out (d,Vp), labels (B,S) -> mean CE (f32 scalar).

    loss_mask (B,S) f32 selects positions (MLM objective); None = all (CLM).
    vocab_real: true vocab when w_out is padded (logits beyond it masked).
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    hs = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    if loss_mask is None:
        ms = jnp.ones((nc, B, chunk), F32)
    else:
        ms = loss_mask.astype(F32).reshape(B, nc, chunk).transpose(1, 0, 2)

    from repro.dist.annotate import constrain

    Vp = w_out.shape[-1]

    @jax.checkpoint
    def step(acc, xs):
        # rematted: the (B, chunk, V) logits/probs are recomputed in the
        # backward pass instead of being saved across the scan — the full
        # (B, S, V) tensor never exists.
        hc, lc, mc = xs
        logits = constrain((hc @ w_out).astype(F32),
                           ("batch", None, "vocab"))   # (B, chunk, Vp)
        if vocab_real is not None and vocab_real < Vp:
            logits = jnp.where(jnp.arange(Vp) < vocab_real, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot, cnt = acc
        return (tot + jnp.sum((lse - gold) * mc), cnt + jnp.sum(mc)), None

    (total, count), _ = jax.lax.scan(
        step, (jnp.zeros((), F32), jnp.zeros((), F32)), (hs, ls, ms))
    return total / jnp.maximum(count, 1.0)


def chunked_kl_loss(h_s, w_s, h_t, w_t, chunk, vocab_real=None):
    """Distillation objective: mean per-position KL(teacher || student)
    over teacher-forced positions, plus the teacher/student argmax
    agreement fraction (the greedy-drafting acceptance proxy).

    h_s/h_t (B,S,d_s)/(B,S,d_t) student/teacher hidden states over the
    SAME token stream; w_s/w_t their unembeddings.  Same rematted chunk
    scan as `chunked_ce_loss` — neither (B, S, V) logits tensor ever
    materializes.  The caller stops gradients through the teacher."""
    B, S, _ = h_s.shape
    assert h_t.shape[:2] == (B, S), (h_s.shape, h_t.shape)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    hs = h_s.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    ht = h_t.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    Vs, Vt = w_s.shape[-1], w_t.shape[-1]

    @jax.checkpoint
    def step(acc, xs):
        hc_s, hc_t = xs
        zs = (hc_s @ w_s).astype(F32)                  # (B, chunk, Vs)
        zt = (hc_t @ w_t).astype(F32)
        if vocab_real is not None:
            zs = jnp.where(jnp.arange(Vs) < vocab_real, zs, -1e30)
            zt = jnp.where(jnp.arange(Vt) < vocab_real, zt, -1e30)
        lps = jax.nn.log_softmax(zs, axis=-1)
        lpt = jax.nn.log_softmax(zt, axis=-1)
        pt = jnp.exp(lpt)
        kl = jnp.sum(pt * (lpt - lps), axis=-1)        # (B, chunk)
        agree = (jnp.argmax(zt, axis=-1)
                 == jnp.argmax(zs, axis=-1)).astype(F32)
        tot, agr, cnt = acc
        return (tot + kl.sum(), agr + agree.sum(),
                cnt + jnp.asarray(kl.size, F32)), None

    (total, agreed, count), _ = jax.lax.scan(
        step, (jnp.zeros((), F32), jnp.zeros((), F32), jnp.zeros((), F32)),
        (hs, ht))
    return total / jnp.maximum(count, 1.0), agreed / jnp.maximum(count, 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    """Mean CE + MoE aux loss.  CLM by default; MLM when batch carries
    loss_mask (the paper's pretraining objective)."""
    h, aux = hidden_states(params, cfg, batch)
    w_out = _unembed_weight(params, cfg)
    labels = batch["labels"]
    ce = chunked_ce_loss(h, w_out, labels, cfg.loss_chunk,
                         loss_mask=batch.get("loss_mask"),
                         vocab_real=cfg.vocab_size)
    return ce + cfg.aux_loss_weight * aux


def logits_fn(params, cfg: ModelConfig, batch):
    """Full logits — small shapes only (tests / examples)."""
    h, _ = hidden_states(params, cfg, batch)
    logits = (h @ _unembed_weight(params, cfg)).astype(F32)
    return logits[..., :cfg.vocab_size]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def init(cfg: ModelConfig, key):
    return init_params(param_spec(cfg), key, cfg.dtype)


def abstract(cfg: ModelConfig):
    return abstract_params(param_spec(cfg), cfg.dtype)
