"""Jit'd public wrappers around the Pallas kernels.

Each op here has a pure-jnp oracle in `repro.kernels.ref` and is swept over
shapes/dtypes in tests/test_kernels.py.  ``interpret=None`` auto-selects
interpret mode on CPU so the same call sites run on TPU and in this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.core.ref_attention import masked_softmax_attention
from repro.kernels import bigbird_attn, wkv6

__all__ = ["bigbird_attention_fused", "wkv6_scan"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _overwrite_global_rows(out, q, k, v, cfg, grp):
    """Dense recompute of the global query rows (paper App. D)."""
    g, b = cfg.num_global_blocks, cfg.block_size
    if not g:
        return out
    S = q.shape[2]
    ng = g * b
    qg = q[:, :, :ng]
    if cfg.causal:
        m = jnp.arange(ng)[:, None] >= jnp.arange(S)[None, :]
    else:
        m = jnp.ones((ng, S), dtype=bool)
    kf = jnp.repeat(k, grp, axis=1) if grp > 1 else k
    vf = jnp.repeat(v, grp, axis=1) if grp > 1 else v
    og = masked_softmax_attention(qg, kf, vf, m, scale=1.0 / np.sqrt(q.shape[-1]))
    return out.at[:, :, :ng].set(og.astype(out.dtype))


def bigbird_attention_fused(q, k, v, cfg: patterns.BigBirdConfig,
                            layer: int = 0, interpret=None):
    """Fused-kernel BigBird attention.  q (B,Hq,S,d); k,v (B,Hkv,S,d)."""
    interpret = _auto_interpret(interpret)
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    grp = Hq // Hkv
    pat = patterns.build_pattern(cfg, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks, jnp.int32)
    msk = jnp.asarray(pat.key_mask.astype(np.int32))
    diag_slot = (cfg.num_global_blocks + cfg.num_window_blocks - 1
                 if cfg.causal else -1)
    out = bigbird_attn.bigbird_attn_pallas(
        q.reshape(B * Hq, S, d), k.reshape(B * Hkv, S, d),
        v.reshape(B * Hkv, S, d), idx, msk,
        block_size=cfg.block_size, grp=grp, diag_slot=diag_slot,
        interpret=interpret)
    out = out.reshape(B, Hq, S, d)
    return _overwrite_global_rows(out, q, k, v, cfg, grp)


def wkv6_scan(r, k, v, w, u, *, chunk: int = 64, interpret=None):
    """RWKV6 WKV recurrence.  r,k,v,w: (B,T,H,D); u: (H,D)."""
    interpret = _auto_interpret(interpret)
    return wkv6.wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)


def mamba_scan(u, dt, bmat, cmat, a_log, d_skip, *, chunk: int = 64,
               di_block: int = 512, interpret=None):
    """Selective-SSM scan.  u,dt (B,T,di); bmat,cmat (B,T,st); a_log (di,st)."""
    from repro.kernels import mamba_scan as mk
    interpret = _auto_interpret(interpret)
    return mk.mamba_scan_pallas(u, dt, bmat, cmat, a_log, d_skip, chunk=chunk,
                                di_block=di_block, interpret=interpret)
