"""Jit'd public wrappers around the Pallas kernels.

Each op here has a pure-jnp oracle in `repro.kernels.ref` and is swept over
shapes/dtypes in tests/test_kernels.py.  ``interpret=None`` auto-selects
interpret mode on CPU so the same call sites run on TPU and in this container.

`bigbird_attention_fused` is fully trainable: a `jax.custom_vjp` pairs the
forward kernel (which saves per-row logsumexp residuals) with flash-style
backward Pallas kernels (dQ over the forward slot map, dK/dV over the
transposed map + a dense reduction for the global key columns).  See
DESIGN.md §Kernel autodiff contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.core.ref_attention import masked_softmax_attention
from repro.kernels import bigbird_attn, ragged_prefill, wkv6

__all__ = ["bigbird_attention_fused", "bigbird_paged_decode_attn",
           "bigbird_ragged_prefill_attn", "wkv6_scan", "mamba_scan"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _global_rows(q, k, v, cfg, grp):
    """Dense attention of the global query rows (paper App. D).

    Differentiable by construction: the backward pass takes jax.vjp of this
    function (recompute policy — no quadratic residual is ever saved).
    Returns (B, Hq, g*b, d).
    """
    g, b = cfg.num_global_blocks, cfg.block_size
    S = q.shape[2]
    ng = g * b
    qg = q[:, :, :ng]
    if cfg.causal:
        m = jnp.arange(ng)[:, None] >= jnp.arange(S)[None, :]
    else:
        m = jnp.ones((ng, S), dtype=bool)
    kf = jnp.repeat(k, grp, axis=1) if grp > 1 else k
    vf = jnp.repeat(v, grp, axis=1) if grp > 1 else v
    return masked_softmax_attention(qg, kf, vf, m, scale=1.0 / np.sqrt(q.shape[-1]))


def _overwrite_global_rows(out, q, k, v, cfg, grp):
    """Dense recompute of the global query rows (paper App. D)."""
    if not cfg.num_global_blocks:
        return out
    ng = cfg.num_global_blocks * cfg.block_size
    og = _global_rows(q, k, v, cfg, grp)
    return out.at[:, :, :ng].set(og.astype(out.dtype))


def _diag_slot(cfg):
    # policy-owned: the slot that references the query's own block (the one
    # the causal kernels refine with the triangular mask) depends on layout
    return patterns.diag_slot(cfg)


def _fused_fwd(q, k, v, cfg, layer, interpret):
    """Sparse kernel + dense global-row overwrite.  Returns (out, lse)."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    grp = Hq // Hkv
    pat = patterns.build_pattern(cfg, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks, jnp.int32)
    msk = jnp.asarray(pat.key_mask.astype(np.int32))
    out, lse = bigbird_attn.bigbird_attn_fwd(
        q.reshape(B * Hq, S, d), k.reshape(B * Hkv, S, d),
        v.reshape(B * Hkv, S, d), idx, msk,
        block_size=cfg.block_size, grp=grp, diag_slot=_diag_slot(cfg),
        interpret=interpret)
    out = out.reshape(B, Hq, S, d)
    out = _overwrite_global_rows(out, q, k, v, cfg, grp)
    return out, lse.reshape(B, Hq, S)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _bigbird_fused(q, k, v, cfg, layer, interpret):
    out, _ = _fused_fwd(q, k, v, cfg, layer, interpret)
    return out


def _bigbird_fused_fwd(q, k, v, cfg, layer, interpret):
    out, lse = _fused_fwd(q, k, v, cfg, layer, interpret)
    return out, (q, k, v, out, lse)


def _bigbird_fused_bwd(cfg, layer, interpret, res, do):
    q, k, v, out, lse = res
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    grp = Hq // Hkv
    b = cfg.block_size
    g = cfg.num_global_blocks
    ng = g * b
    pat = patterns.build_pattern(cfg, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks, jnp.int32)
    msk = jnp.asarray(pat.key_mask.astype(np.int32))

    # gradient of the dense-recomputed global query rows does NOT flow
    # through the sparse kernel (their kernel output was overwritten)
    do_s = do.at[:, :, :ng].set(0.0) if g else do
    dof = do_s.astype(jnp.float32)
    delta = jnp.sum(dof * out.astype(jnp.float32), axis=-1)      # (B,Hq,S)

    q3 = q.reshape(B * Hq, S, d)
    k3 = k.reshape(B * Hkv, S, d)
    v3 = v.reshape(B * Hkv, S, d)
    do3 = do_s.reshape(B * Hq, S, d)
    lse3 = lse.reshape(B * Hq, S)
    dl3 = delta.reshape(B * Hq, S)

    dq = bigbird_attn.bigbird_attn_dq(
        q3, k3, v3, do3, lse3, dl3, idx, msk, block_size=b, grp=grp,
        diag_slot=_diag_slot(cfg), interpret=interpret)          # (BHq,S,d) f32

    tq, tmsk = patterns.transposed_pattern(cfg, S, layer=layer)
    if tmsk.any():
        dk_h, dv_h = bigbird_attn.bigbird_attn_dkv(
            q3, k3, v3, do3, lse3, dl3,
            jnp.asarray(tq, jnp.int32), jnp.asarray(tmsk.astype(np.int32)),
            block_size=b, grp=grp, causal=cfg.causal, interpret=interpret)
    else:
        dk_h = jnp.zeros((B * Hq, S, d), jnp.float32)
        dv_h = jnp.zeros((B * Hq, S, d), jnp.float32)
    if g:
        dk_g, dv_g = bigbird_attn.bigbird_attn_dkv_global(
            q3, k3, v3, do3, lse3, dl3, block_size=b, grp=grp,
            num_global_blocks=g, interpret=interpret)
        dk_h = dk_h.at[:, :ng].add(dk_g)
        dv_h = dv_h.at[:, :ng].add(dv_g)

    dq = dq.reshape(B, Hq, S, d)
    dk = dk_h.reshape(B, Hkv, grp, S, d).sum(axis=2)             # GQA group sum
    dv = dv_h.reshape(B, Hkv, grp, S, d).sum(axis=2)

    if g:
        # dense global-row recompute: its dK/dV span the whole sequence
        og, gvjp = jax.vjp(lambda q_, k_, v_: _global_rows(q_, k_, v_, cfg, grp),
                           q, k, v)
        dq_g, dk_g2, dv_g2 = gvjp(do[:, :, :ng].astype(og.dtype))
        dq = dq + dq_g.astype(jnp.float32)
        dk = dk + dk_g2.astype(jnp.float32)
        dv = dv + dv_g2.astype(jnp.float32)

    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_bigbird_fused.defvjp(_bigbird_fused_fwd, _bigbird_fused_bwd)


def bigbird_attention_fused(q, k, v, cfg: patterns.BigBirdConfig,
                            layer: int = 0, interpret=None):
    """Fused-kernel BigBird attention.  q (B,Hq,S,d); k,v (B,Hkv,S,d).

    Trainable: jax.grad/value_and_grad flow through custom Pallas backward
    kernels (flash-style recompute; nothing quadratic is materialized).
    """
    interpret = _auto_interpret(interpret)
    return _bigbird_fused(q, k, v, cfg, layer, interpret)


def bigbird_paged_decode_attn(q, kc, vc, page_tables, pos,
                              cfg: patterns.BigBirdConfig, layer: int = 0,
                              interpret=None, k_scale=None, v_scale=None):
    """Paged bounded-decode read via the scalar-prefetched Pallas kernel.

    q (B, Hq, 1, dh); kc/vc (P, Hkv, b, dh) — flat physical page stores;
    page_tables (B, max_pages) int32; pos (B,) int32.  Forward-only (the
    serving decode path never differentiates; DESIGN.md §Paged cache).
    `k_scale`/`v_scale` (P, Hkv) f32 — int8 stores' per-(page, head)
    scales, dequantized inline in VMEM after the page gather.
    The XLA two-level gather in models/decode._bigbird_decode_attn_paged
    is the parity baseline (tests/test_kernels.py)."""
    interpret = _auto_interpret(interpret)
    B, Hq, _, dh = q.shape
    Hkv = kc.shape[1]
    grp = Hq // Hkv
    b = cfg.block_size
    S = page_tables.shape[1] * b
    pat = patterns.build_pattern(cfg, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks, jnp.int32)
    msk = jnp.asarray(pat.key_mask.astype(np.int32))
    out = bigbird_attn.bigbird_paged_decode(
        q[:, :, 0], kc, vc, jnp.asarray(page_tables, jnp.int32),
        jnp.asarray(pos, jnp.int32), idx, msk, k_scale, v_scale,
        block_size=b, grp=grp, interpret=interpret)
    return out[:, :, None].astype(q.dtype)


def bigbird_ragged_prefill_attn(q, kc, vc, page_tables, starts,
                                cfg: patterns.BigBirdConfig, layer: int = 0,
                                interpret=None, k_scale=None, v_scale=None):
    """Ragged multi-prompt prefill-chunk read via the Pallas kernel.

    q (B, Hq, C, dh) — one chunk of queries per row, row i at positions
    [starts[i], starts[i]+C); kc/vc (P, Hkv, b, dh) — flat physical page
    stores with the chunk's K/V already written; page_tables (B, max_pages)
    int32; starts (B,) int32, page-aligned and >= g*b (global query rows
    need the dense path — the Engine never routes them here).  Forward-only.
    `k_scale`/`v_scale` (P, Hkv) f32 — int8 stores' per-(page, head)
    scales, dequantized inline in VMEM after the page gather.
    The XLA gather in models/decode._ragged_attn_layer is the parity
    baseline (tests/test_kernels.py)."""
    interpret = _auto_interpret(interpret)
    B, Hq, C, dh = q.shape
    Hkv = kc.shape[1]
    grp = Hq // Hkv
    b = cfg.block_size
    S = page_tables.shape[1] * b
    pat = patterns.build_pattern(cfg, S, layer=layer)
    idx = jnp.asarray(pat.key_blocks, jnp.int32)
    msk = jnp.asarray(pat.key_mask.astype(np.int32))
    return ragged_prefill.bigbird_ragged_prefill(
        q, kc, vc, jnp.asarray(page_tables, jnp.int32),
        jnp.asarray(starts, jnp.int32), idx, msk, k_scale, v_scale,
        block_size=b, grp=grp, interpret=interpret).astype(q.dtype)


def wkv6_scan(r, k, v, w, u, *, chunk: int = 64, interpret=None):
    """RWKV6 WKV recurrence.  r,k,v,w: (B,T,H,D); u: (H,D)."""
    interpret = _auto_interpret(interpret)
    return wkv6.wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)


def mamba_scan(u, dt, bmat, cmat, a_log, d_skip, *, chunk: int = 64,
               di_block: int = 512, interpret=None):
    """Selective-SSM scan.  u,dt (B,T,di); bmat,cmat (B,T,st); a_log (di,st)."""
    from repro.kernels import mamba_scan as mk
    interpret = _auto_interpret(interpret)
    return mk.mamba_scan_pallas(u, dt, bmat, cmat, a_log, d_skip, chunk=chunk,
                                di_block=di_block, interpret=interpret)
