"""Pallas TPU kernels for compute hot-spots (fused BigBird block-sparse
attention fwd/bwd, paged decode, ragged prefill) plus pure-JAX references
used for interpret-mode parity tests."""
