"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import patterns
from repro.core.ref_attention import bigbird_attention_reference

__all__ = ["bigbird_attention_ref", "wkv6_ref", "mamba_scan_ref"]


def mamba_scan_ref(u, dt, bmat, cmat, a_log, d_skip):
    """Sequential-scan oracle for the selective-SSM recurrence."""
    from repro.models.layers import _mamba_scan
    y, _ = _mamba_scan(u, dt, a_log, bmat, cmat, d_skip)
    return y


def bigbird_attention_ref(q, k, v, cfg: patterns.BigBirdConfig, layer: int = 0):
    """Dense-mask oracle (O(n^2)); see core.ref_attention."""
    return bigbird_attention_reference(q, k, v, cfg, layer=layer)


def wkv6_ref(r, k, v, w, u):
    """Sequential-scan oracle for the WKV6 recurrence.

    r,k,v,w: (B, T, H, D); u: (H, D) -> (B, T, H, D).
    """
    B, T, H, D = r.shape
    rf = r.transpose(1, 0, 2, 3).astype(jnp.float32)     # (T, B, H, D)
    kf = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vf = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    wf = w.transpose(1, 0, 2, 3).astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(s, xs):
        """One WKV recurrence step: emit y_t, decay and rank-1-update s."""
        rt, kt, vt, wt = xs                               # (B, H, D)
        # y[b,h,dv] = sum_dk rt[b,h,dk] * (s[b,h,dk,dv] + u[h,dk]*kt[b,h,dk]*vt[b,h,dv])
        y = jnp.einsum("bhk,bhkv->bhv", rt, s)
        y += jnp.einsum("bhk,bhv->bhv", rt * uf[None] * kt, vt)
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, y

    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (rf, kf, vf, wf))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)
