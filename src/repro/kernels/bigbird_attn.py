"""Fused BigBird block-sparse attention — Pallas TPU kernels (fwd + bwd).

Beyond-paper optimization (the paper materializes the packed key tensor K''
in HBM, App. D Fig. 6): these kernels fuse the packing, QK^T, softmax and AV
into one pass.  The packed tensor never exists — key/value blocks are pulled
HBM->VMEM directly via scalar-prefetched index maps, and a flash-attention
style streaming softmax keeps only (b, d) accumulators in VMEM.

Forward grid: (B*Hq, nb, L) — one query block per (bh, j), iterating its
L = g+w+r key-block slots in the innermost (sequential on TPU) dimension.
The forward also emits the per-row logsumexp so the backward can recompute
probabilities flash-style (nothing quadratic is ever materialized).

Backward (see ops.bigbird_attention_fused for the custom_vjp wiring):
  * dQ    — same (bh, j, t) grid and slot maps as the forward; per slot it
            recomputes p = exp(s - lse) and accumulates ds @ k.
  * dK/dV — the slot map is *transposed* host-side (patterns.transposed_
            pattern): grid (bh, i, u) iterates, for key block i, the u-th
            query block that attends it.  Only window/random slots live in
            the transposed map, bounding its padded width by the max
            in-degree (O(w + r) non-causal, ~ w + r·log(nb) causal).
  * dK/dV global columns — key blocks < g are referenced by *every* query
            row; a dedicated (bh, i, j) grid reduces over all nb query
            blocks (linear work: g * nb cells).

Scalar-prefetch operands (compile-time-shaped, data-dependent indexing):
  idx  (nb, L) int32 — key block index per slot (from core.patterns).
  msk  (nb, L) int32 — 1 if the slot is live, 0 if duplicate/out-of-range.
  tq   (nb, U) int32 — transposed map: query blocks per key block.
  tmsk (nb, U) int32 — transposed-map validity.

VMEM working set per grid cell: q (b,d) + k (b,d) + v (b,d) + acc (b,d)
+ scores (b,b) + m,l (b,1)  ≈ 4*b*d + b*b floats; with b=64, d=128 that is
~0.16 MB — far under the ~16 MB v5e VMEM budget, leaving room for the
compiler to double-buffer the k/v streams across slots.

Global *query* rows (blocks 0..g-1) attend to everything; they are recomputed
densely by the wrapper in `repro.kernels.ops` (paper does the same), in both
the forward and the backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LSE_EMPTY = 1e30      # lse sentinel for rows with no live key: exp(s-lse)=0


def _tri(block_size: int):
    """(b, b) lower-triangular mask: query row >= key col (self block)."""
    b = block_size
    row = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    return row >= col


def _slot_mask(msk_ref, s_shape, j, t, diag_slot: int, block_size: int):
    """Validity mask for slot t of query block j (shared by fwd and dQ)."""
    live = msk_ref[j, t] > 0                             # slot-level validity
    mask = jnp.full(s_shape, live)
    if diag_slot >= 0:
        # causal patterns: the offset-0 window slot needs a triangular mask
        mask = jnp.where(t == diag_slot, mask & _tri(block_size), mask)
    return mask


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _fwd_kernel(idx_ref, msk_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_ref, l_ref, acc_ref, *, scale: float, diag_slot: int,
                num_slots: int, block_size: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (b, d)
    k = k_ref[0].astype(jnp.float32)                     # (b, d)
    v = v_ref[0].astype(jnp.float32)                     # (b, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    j = pl.program_id(1)
    mask = _slot_mask(msk_ref, s.shape, j, t, diag_slot, block_size)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)            # (b, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(t == num_slots - 1)
    def _finish():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lse = jnp.where(l > 0, m_ref[...] + jnp.log(denom), LSE_EMPTY)
        lse_ref[0] = lse[:, 0]


@functools.partial(jax.jit, static_argnames=(
    "block_size", "grp", "diag_slot", "interpret"))
def bigbird_attn_fwd(q, k, v, idx, msk, *, block_size: int, grp: int,
                     diag_slot: int = -1, interpret: bool = False):
    """q: (BHq, S, d); k, v: (BHkv, S, d); idx/msk: (nb, L) int32.

    ``grp`` = Hq // Hkv (GQA group); query row bh reads kv row bh // grp.
    Returns (out (BHq, S, d), lse (BHq, S) float32).  Rows of global query
    blocks are garbage here and must be overwritten by the caller (see
    ops.bigbird_attention_fused).
    """
    BH, S, d = q.shape
    b = block_size
    nb = S // b
    L = idx.shape[1]
    scale = 1.0 / np.sqrt(d)

    grid = (BH, nb, L)
    kernel = functools.partial(_fwd_kernel, scale=scale, diag_slot=diag_slot,
                               num_slots=L, block_size=b)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, b, d), lambda bh, j, t, idx, msk: (bh, j, 0)),
                pl.BlockSpec((1, b, d),
                             lambda bh, j, t, idx, msk: (bh // grp, idx[j, t], 0)),
                pl.BlockSpec((1, b, d),
                             lambda bh, j, t, idx, msk: (bh // grp, idx[j, t], 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, b, d), lambda bh, j, t, idx, msk: (bh, j, 0)),
                pl.BlockSpec((1, b), lambda bh, j, t, idx, msk: (bh, j)),
            ],
            scratch_shapes=[
                pltpu.VMEM((b, 1), jnp.float32),
                pltpu.VMEM((b, 1), jnp.float32),
                pltpu.VMEM((b, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, d), q.dtype),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        interpret=interpret,
    )(idx, msk, q, k, v)


# --------------------------------------------------------------------------
# backward: dQ — same grid and slot maps as the forward
# --------------------------------------------------------------------------

def _dq_kernel(idx_ref, msk_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
               delta_ref, dq_ref, acc_ref, *, scale: float, diag_slot: int,
               num_slots: int, block_size: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (b, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]                            # (b, 1)
    delta = delta_ref[0][:, None]                        # (b, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    j = pl.program_id(1)
    mask = _slot_mask(msk_ref, s.shape, j, t, diag_slot, block_size)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)           # (b, b) normalized
    dov = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ds = p * (dov - delta)
    acc_ref[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(t == num_slots - 1)
    def _finish():
        dq_ref[0] = acc_ref[...] * scale


@functools.partial(jax.jit, static_argnames=(
    "block_size", "grp", "diag_slot", "interpret"))
def bigbird_attn_dq(q, k, v, do, lse, delta, idx, msk, *, block_size: int,
                    grp: int, diag_slot: int = -1, interpret: bool = False):
    """dQ for the sparse rows.  Returns (BHq, S, d) float32.

    ``do`` must have the global query rows zeroed (their gradient flows
    through the dense recompute, not this kernel); ``delta = sum(do*out, -1)``.
    """
    BH, S, d = q.shape
    b = block_size
    nb = S // b
    L = idx.shape[1]
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_dq_kernel, scale=scale, diag_slot=diag_slot,
                               num_slots=L, block_size=b)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(BH, nb, L),
            in_specs=[
                pl.BlockSpec((1, b, d), lambda bh, j, t, idx, msk: (bh, j, 0)),
                pl.BlockSpec((1, b, d),
                             lambda bh, j, t, idx, msk: (bh // grp, idx[j, t], 0)),
                pl.BlockSpec((1, b, d),
                             lambda bh, j, t, idx, msk: (bh // grp, idx[j, t], 0)),
                pl.BlockSpec((1, b, d), lambda bh, j, t, idx, msk: (bh, j, 0)),
                pl.BlockSpec((1, b), lambda bh, j, t, idx, msk: (bh, j)),
                pl.BlockSpec((1, b), lambda bh, j, t, idx, msk: (bh, j)),
            ],
            out_specs=pl.BlockSpec((1, b, d),
                                   lambda bh, j, t, idx, msk: (bh, j, 0)),
            scratch_shapes=[pltpu.VMEM((b, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), jnp.float32),
        interpret=interpret,
    )(idx, msk, q, k, v, do, lse, delta)


# --------------------------------------------------------------------------
# backward: dK/dV over window+random slots — transposed slot map
# --------------------------------------------------------------------------

def _dkv_kernel(tq_ref, tmsk_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                causal: bool, num_rev: int, block_size: int):
    u = pl.program_id(2)

    @pl.when(u == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    i = pl.program_id(1)                                 # key block
    j = tq_ref[i, u]                                     # query block
    live = tmsk_ref[i, u] > 0

    q = q_ref[0].astype(jnp.float32)                     # (b, d) query block j
    k = k_ref[0].astype(jnp.float32)                     # (b, d) key block i
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = jnp.full(s.shape, live)
    if causal:
        # the only self-referencing slot is the offset-0 window slot (j == i)
        mask = jnp.where(j == i, mask & _tri(block_size), mask)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)           # (b_q, b_k)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dov = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ds = p * (dov - delta)
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(u == num_rev - 1)
    def _finish():
        dk_ref[0] = dk_acc[...] * scale
        dv_ref[0] = dv_acc[...]


@functools.partial(jax.jit, static_argnames=(
    "block_size", "grp", "causal", "interpret"))
def bigbird_attn_dkv(q, k, v, do, lse, delta, tq, tmsk, *, block_size: int,
                     grp: int, causal: bool, interpret: bool = False):
    """dK/dV contributions of the window+random slots, per *query* head.

    Grid (BHq, nb, U): key block i accumulates over the U query blocks that
    attend it (transposed map).  Returns (dk, dv), each (BHq, S, d) float32;
    the caller sums heads over the GQA group down to BHkv.
    """
    BH, S, d = q.shape
    b = block_size
    nb = S // b
    U = tq.shape[1]
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_dkv_kernel, scale=scale, causal=causal,
                               num_rev=U, block_size=b)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(BH, nb, U),
            in_specs=[
                pl.BlockSpec((1, b, d), lambda bh, i, u, tq, tm: (bh, tq[i, u], 0)),
                pl.BlockSpec((1, b, d), lambda bh, i, u, tq, tm: (bh // grp, i, 0)),
                pl.BlockSpec((1, b, d), lambda bh, i, u, tq, tm: (bh // grp, i, 0)),
                pl.BlockSpec((1, b, d), lambda bh, i, u, tq, tm: (bh, tq[i, u], 0)),
                pl.BlockSpec((1, b), lambda bh, i, u, tq, tm: (bh, tq[i, u])),
                pl.BlockSpec((1, b), lambda bh, i, u, tq, tm: (bh, tq[i, u])),
            ],
            out_specs=[
                pl.BlockSpec((1, b, d), lambda bh, i, u, tq, tm: (bh, i, 0)),
                pl.BlockSpec((1, b, d), lambda bh, i, u, tq, tm: (bh, i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((b, d), jnp.float32),
                pltpu.VMEM((b, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, d), jnp.float32),
            jax.ShapeDtypeStruct((BH, S, d), jnp.float32),
        ],
        interpret=interpret,
    )(tq, tmsk, q, k, v, do, lse, delta)


# --------------------------------------------------------------------------
# backward: dK/dV over the global key columns (blocks < g)
# --------------------------------------------------------------------------

def _dkv_global_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                       num_qblocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]

    # global slots are live for every query row; rows whose gradient must not
    # flow here (the dense-recomputed global query rows) arrive with do == 0.
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    p = jnp.exp(s - lse)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dov = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ds = p * (dov - delta)
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(j == num_qblocks - 1)
    def _finish():
        dk_ref[0] = dk_acc[...] * scale
        dv_ref[0] = dv_acc[...]


@functools.partial(jax.jit, static_argnames=(
    "block_size", "grp", "num_global_blocks", "interpret"))
def bigbird_attn_dkv_global(q, k, v, do, lse, delta, *, block_size: int,
                            grp: int, num_global_blocks: int,
                            interpret: bool = False):
    """dK/dV for the global key blocks (< g), reduced over ALL query blocks.

    Grid (BHq, g, nb) — linear work.  Returns (dk_g, dv_g), each
    (BHq, g*b, d) float32, per query head (caller sums the GQA group).
    """
    BH, S, d = q.shape
    b = block_size
    nb = S // b
    g = num_global_blocks
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_dkv_global_kernel, scale=scale, num_qblocks=nb)
    return pl.pallas_call(
        kernel,
        grid=(BH, g, nb),
        in_specs=[
            pl.BlockSpec((1, b, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, b, d), lambda bh, i, j: (bh // grp, i, 0)),
            pl.BlockSpec((1, b, d), lambda bh, i, j: (bh // grp, i, 0)),
            pl.BlockSpec((1, b, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, b), lambda bh, i, j: (bh, j)),
            pl.BlockSpec((1, b), lambda bh, i, j: (bh, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, b, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, b, d), lambda bh, i, j: (bh, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, d), jnp.float32),
            pltpu.VMEM((b, d), jnp.float32),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, g * b, d), jnp.float32),
            jax.ShapeDtypeStruct((BH, g * b, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)


# --------------------------------------------------------------------------
# paged bounded decode (forward-only, serving path)
# --------------------------------------------------------------------------

def _paged_decode_inner(i, t, pos_ref, idx_ref, msk_ref, q_ref, k, v, o_ref,
                        m_ref, l_ref, acc_ref, *, scale: float,
                        block_size: int, grp: int, num_slots: int):
    """Shared flash-softmax body; k/v (Hkv, b, d) arrive already in f32
    (the int8 wrapper dequantizes them in VMEM before calling in)."""
    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = block_size
    pos = pos_ref[i]
    jq = pos // b                                        # query's logical block
    blk = idx_ref[jq, t]                                 # logical key block
    live = msk_ref[jq, t] > 0
    # logical key positions inside this page; strict bound <= pos
    kpos = blk * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    valid = live & (kpos <= pos)                         # (1, b)

    q = q_ref[0].astype(jnp.float32)                     # (Hq, d)
    hq, d = q.shape
    hkv = k.shape[0]
    qg = q.reshape(hkv, grp, d)
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(hq, b)
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)            # (Hq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    pg = p.reshape(hkv, grp, b)
    pv = jax.lax.dot_general(pg, v, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv.reshape(hq, d)

    @pl.when(t == num_slots - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _paged_decode_kernel(pt_ref, pos_ref, idx_ref, msk_ref, q_ref, k_ref,
                         v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                         block_size: int, grp: int, num_slots: int):
    i = pl.program_id(0)                                 # slot (batch row)
    t = pl.program_id(1)                                 # pattern slot
    _paged_decode_inner(i, t, pos_ref, idx_ref, msk_ref, q_ref,
                        k_ref[0].astype(jnp.float32),
                        v_ref[0].astype(jnp.float32),
                        o_ref, m_ref, l_ref, acc_ref, scale=scale,
                        block_size=block_size, grp=grp, num_slots=num_slots)


def _paged_decode_kernel_q(pt_ref, pos_ref, idx_ref, msk_ref, q_ref, k_ref,
                           v_ref, ks_ref, vs_ref, o_ref, m_ref, l_ref,
                           acc_ref, *, scale: float, block_size: int,
                           grp: int, num_slots: int):
    """int8-page variant: the page and its (1, Hkv) scales arrive through
    the same scalar-prefetched gather; dequant happens here in VMEM,
    before the contraction ever sees the rows."""
    i = pl.program_id(0)
    t = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None, None]
    v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None, None]
    _paged_decode_inner(i, t, pos_ref, idx_ref, msk_ref, q_ref, k, v,
                        o_ref, m_ref, l_ref, acc_ref, scale=scale,
                        block_size=block_size, grp=grp, num_slots=num_slots)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "grp", "interpret"))
def bigbird_paged_decode(q, kc, vc, page_tables, pos, idx, msk,
                         k_scale=None, v_scale=None, *,
                         block_size: int, grp: int, interpret: bool = False):
    """Paged bounded-decode attention (forward-only, serving hot path).

    q (B, Hq, d) — one new token per slot; kc/vc (P, Hkv, b, d) — the flat
    physical page store; page_tables (B, max_pages) int32; pos (B,) int32;
    idx/msk (nb, L) int32 — the pattern slot maps at the LOGICAL cache
    length nb = max_pages.

    Grid (B, L): cell (i, t) resolves pattern slot t of slot i's current
    query block through two scalar-prefetched levels — pattern block
    `idx[pos[i]//b, t]`, then physical page `pt[i, ...]` — and streams the
    page through a flash-style softmax.  The packed key tensor never
    exists, and (unlike the slot-contiguous XLA gather) no (B, L*b) HBM
    re-materialization happens either: pages go HBM->VMEM once.
    `grp` = Hq // Hkv (GQA): query head h reads kv head h // grp.

    `k_scale`/`v_scale` (P, Hkv) f32 — per-(page, head) scales of int8
    stores; each grid cell prefetches its page's scale row alongside the
    page and dequantizes inline in VMEM."""
    B, Hq, d = q.shape
    b = block_size
    L = idx.shape[1]
    scale = 1.0 / np.sqrt(d)
    Hkv = kc.shape[1]

    def _slot(i, t, pt, pos, idx, msk):
        return (i, 0, 0)

    def _page(i, t, pt, pos, idx, msk):
        return (pt[i, idx[pos[i] // b, t]], 0, 0, 0)

    def _pscale(i, t, pt, pos, idx, msk):
        return (pt[i, idx[pos[i] // b, t]], 0)

    quant = k_scale is not None
    kern = _paged_decode_kernel_q if quant else _paged_decode_kernel
    kernel = functools.partial(kern, scale=scale,
                               block_size=b, grp=grp, num_slots=L)
    in_specs = [
        pl.BlockSpec((1, Hq, d), _slot),
        pl.BlockSpec((1, Hkv, b, d), _page),
        pl.BlockSpec((1, Hkv, b, d), _page),
    ]
    operands = (q, kc, vc)
    if quant:
        in_specs += [pl.BlockSpec((1, Hkv), _pscale),
                     pl.BlockSpec((1, Hkv), _pscale)]
        operands = (q, kc, vc, k_scale, v_scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(B, L),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, Hq, d), _slot),
            scratch_shapes=[
                pltpu.VMEM((Hq, 1), jnp.float32),
                pltpu.VMEM((Hq, 1), jnp.float32),
                pltpu.VMEM((Hq, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, d), q.dtype),
        interpret=interpret,
    )(page_tables, pos, idx, msk, *operands)
