"""Fused BigBird block-sparse attention — Pallas TPU kernel.

Beyond-paper optimization (the paper materializes the packed key tensor K''
in HBM, App. D Fig. 6): this kernel fuses the packing, QK^T, softmax and AV
into one pass.  The packed tensor never exists — key/value blocks are pulled
HBM->VMEM directly via scalar-prefetched index maps, and a flash-attention
style streaming softmax keeps only (b, d) accumulators in VMEM.

Grid: (B*Hq, nb, L) — one query block per (bh, j), iterating its L = g+w+r
key-block slots in the innermost (sequential on TPU) dimension.

Scalar-prefetch operands (compile-time-shaped, data-dependent indexing):
  idx  (nb, L) int32 — key block index per slot (from core.patterns).
  msk  (nb, L) int32 — 1 if the slot is live, 0 if duplicate/out-of-range.

VMEM working set per grid cell: q (b,d) + k (b,d) + v (b,d) + acc (b,d)
+ scores (b,b) + m,l (b,1)  ≈ 4*b*d + b*b floats; with b=64, d=128 that is
~0.16 MB — far under the ~16 MB v5e VMEM budget, leaving room for the
compiler to double-buffer the k/v streams across slots.

Global *query* rows (blocks 0..g-1) attend to everything; they are recomputed
densely by the wrapper in `repro.kernels.ops` (paper does the same).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(idx_ref, msk_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, diag_slot: int,
            num_slots: int, block_size: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (b, d)
    k = k_ref[0].astype(jnp.float32)                     # (b, d)
    v = v_ref[0].astype(jnp.float32)                     # (b, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    j = pl.program_id(1)
    live = msk_ref[j, t] > 0                             # slot-level validity
    mask = jnp.full(s.shape, live)
    if diag_slot >= 0:
        # causal patterns: the offset-0 window slot needs a triangular mask
        b = block_size
        row = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
        tri = row >= col
        mask = jnp.where(t == diag_slot, mask & tri, mask)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)            # (b, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(t == num_slots - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "grp", "diag_slot", "interpret"))
def bigbird_attn_pallas(q, k, v, idx, msk, *, block_size: int, grp: int,
                        diag_slot: int = -1, interpret: bool = False):
    """q: (BHq, S, d); k, v: (BHkv, S, d); idx/msk: (nb, L) int32.

    ``grp`` = Hq // Hkv (GQA group); query row bh reads kv row bh // grp.
    Returns (BHq, S, d).  Rows of global query blocks are garbage here and
    must be overwritten by the caller (see ops.bigbird_attention).
    """
    BH, S, d = q.shape
    b = block_size
    nb = S // b
    L = idx.shape[1]
    scale = 1.0 / np.sqrt(d)

    grid = (BH, nb, L)
    kernel = functools.partial(_kernel, scale=scale, diag_slot=diag_slot,
                               num_slots=L, block_size=b)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, b, d), lambda bh, j, t, idx, msk: (bh, j, 0)),
                pl.BlockSpec((1, b, d),
                             lambda bh, j, t, idx, msk: (bh // grp, idx[j, t], 0)),
                pl.BlockSpec((1, b, d),
                             lambda bh, j, t, idx, msk: (bh // grp, idx[j, t], 0)),
            ],
            out_specs=pl.BlockSpec((1, b, d), lambda bh, j, t, idx, msk: (bh, j, 0)),
            scratch_shapes=[
                pltpu.VMEM((b, 1), jnp.float32),
                pltpu.VMEM((b, 1), jnp.float32),
                pltpu.VMEM((b, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        interpret=interpret,
    )(idx, msk, q, k, v)
