"""Ragged multi-prompt prefill attention — Pallas TPU kernel (forward-only).

The serving Engine batches prefill chunks from several co-admitted prompts
into ONE forward (serve/engine.py §ragged prefill): every batch row carries
its own chunk offset `starts[i]`, so rows sit at *different* logical blocks
of their own paged caches.  This kernel is the sparse-attention read for
that batched chunk: grid cell (i, n, t) resolves pattern slot t of row i's
n-th query block through two scalar-prefetched levels — logical key block
`idx[starts[i]//b + n, t]`, then physical page `pt[i, ...]` — and streams
the page through a flash-style online softmax, exactly the paged-decode
kernel's addressing scheme lifted from one query token to a block of `b`
queries per cell.

Rows are independent: a padding/idle row (dump-page table) computes finite
garbage that the caller discards.  Global *query* rows (blocks < g) need
dense attention over the whole prefix and are NOT handled here — the
Engine only routes chunks with `start >= g*b` to the ragged path, so every
query this kernel sees reads pattern slots only.

The XLA two-level gather in models/decode._ragged_attn_layer is the parity
baseline (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ragged_prefill_inner(
    i, n, t, starts_ref, idx_ref, msk_ref, q_ref, k, v, o_ref, m_ref, l_ref,
    acc_ref, *, scale: float, block_size: int, grp: int, num_slots: int,
):
    """Shared flash-softmax body; k/v (Hkv, b, d) arrive already in f32
    (the int8 wrapper dequantizes them in VMEM before calling in)."""
    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = block_size
    nbp = idx_ref.shape[0]  # logical blocks
    jq = jnp.minimum(starts_ref[i] // b + n, nbp - 1)  # row's query block
    blk = idx_ref[jq, t]  # logical key block
    live = msk_ref[jq, t] > 0
    # causal masking at token granularity: key position <= query position
    qpos = starts_ref[i] + n * b + jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    kpos = blk * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    valid = live & (kpos <= qpos)  # (b, b)

    q = q_ref[0].astype(jnp.float32)  # (Hq, b, d)
    hq, bq, d = q.shape
    hkv = k.shape[0]
    qg = q.reshape(hkv, grp * bq, d)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    s = s.reshape(hq, bq, b) * scale
    s = jnp.where(valid[None], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=2, keepdims=True)  # (Hq, b, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None], p, 0.0)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=2, keepdims=True)
    m_ref[...] = m_new
    pg = p.reshape(hkv, grp * bq, b)
    pv = jax.lax.dot_general(
        pg, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha + pv.reshape(hq, bq, d)

    @pl.when(t == num_slots - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _ragged_prefill_kernel(
    pt_ref, starts_ref, idx_ref, msk_ref, q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref, *, scale, block_size, grp, num_slots,
):
    i = pl.program_id(0)  # batch row
    n = pl.program_id(1)  # chunk query block
    t = pl.program_id(2)  # pattern slot
    _ragged_prefill_inner(
        i, n, t, starts_ref, idx_ref, msk_ref, q_ref,
        k_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
        o_ref, m_ref, l_ref, acc_ref, scale=scale, block_size=block_size,
        grp=grp, num_slots=num_slots)


def _ragged_prefill_kernel_q(
    pt_ref, starts_ref, idx_ref, msk_ref, q_ref, k_ref, v_ref, ks_ref,
    vs_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, block_size, grp,
    num_slots,
):
    """int8-page variant: dequantize the gathered page with its prefetched
    (1, Hkv) scale row in VMEM before the flash-softmax body."""
    i = pl.program_id(0)
    n = pl.program_id(1)
    t = pl.program_id(2)
    k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None, None]
    v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None, None]
    _ragged_prefill_inner(
        i, n, t, starts_ref, idx_ref, msk_ref, q_ref, k, v,
        o_ref, m_ref, l_ref, acc_ref, scale=scale, block_size=block_size,
        grp=grp, num_slots=num_slots)


@functools.partial(jax.jit, static_argnames=("block_size", "grp", "interpret"))
def bigbird_ragged_prefill(
    q,
    kc,
    vc,
    page_tables,
    starts,
    idx,
    msk,
    k_scale=None,
    v_scale=None,
    *,
    block_size: int,
    grp: int,
    interpret: bool = False,
):
    """Ragged paged prefill-chunk attention (forward-only, serving path).

    q (B, Hq, C, d) — one chunk of C = nc*b queries per row, row i covering
    positions [starts[i], starts[i]+C); kc/vc (P, Hkv, b, d) — the flat
    physical page stores (the chunk's K/V already written through the page
    tables by the caller); page_tables (B, max_pages) int32; starts (B,)
    int32, page-aligned; idx/msk (nb, L) int32 — the pattern slot maps at
    the LOGICAL cache length nb = max_pages.

    Grid (B, nc, L): cell (i, n, t) is query block `starts[i]//b + n` of
    row i attending its t-th pattern slot.  `grp` = Hq // Hkv (GQA).

    `k_scale`/`v_scale` (P, Hkv) f32 — per-(page, head) scales of int8
    stores, prefetch-gathered with the page and dequantized in VMEM."""
    B, Hq, C, d = q.shape
    b = block_size
    nc = C // b
    L = idx.shape[1]
    scale = 1.0 / np.sqrt(d)
    Hkv = kc.shape[1]
    nbp = idx.shape[0]

    def _chunk(i, n, t, pt, st, idx, msk):
        return (i, 0, n, 0)

    def _page(i, n, t, pt, st, idx, msk):
        jq = jnp.minimum(st[i] // b + n, nbp - 1)
        return (pt[i, idx[jq, t]], 0, 0, 0)

    def _pscale(i, n, t, pt, st, idx, msk):
        jq = jnp.minimum(st[i] // b + n, nbp - 1)
        return (pt[i, idx[jq, t]], 0)

    quant = k_scale is not None
    kern = _ragged_prefill_kernel_q if quant else _ragged_prefill_kernel
    kernel = functools.partial(
        kern, scale=scale, block_size=b, grp=grp, num_slots=L
    )
    in_specs = [
        pl.BlockSpec((1, Hq, b, d), _chunk),
        pl.BlockSpec((1, Hkv, b, d), _page),
        pl.BlockSpec((1, Hkv, b, d), _page),
    ]
    operands = (q, kc, vc)
    if quant:
        in_specs += [pl.BlockSpec((1, Hkv), _pscale),
                     pl.BlockSpec((1, Hkv), _pscale)]
        operands = (q, kc, vc, k_scale, v_scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(B, nc, L),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, Hq, b, d), _chunk),
            scratch_shapes=[
                pltpu.VMEM((Hq, b, 1), jnp.float32),
                pltpu.VMEM((Hq, b, 1), jnp.float32),
                pltpu.VMEM((Hq, b, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, C, d), q.dtype),
        interpret=interpret,
    )(page_tables, starts, idx, msk, *operands)
