"""Selective-SSM (Mamba) scan — Pallas TPU kernel.

The XLA lowering of the recurrence round-trips the (B, d_inner, d_state)
state through HBM every `unroll` steps; this kernel keeps the state in VMEM
scratch across the whole sequence (the TPU analogue of the CUDA selective
scan that keeps state in registers).  HBM traffic collapses to the
(B, T, d_inner) inputs/outputs — the fix for the jamba memory roofline
(§Perf).

    h_t = exp(dt_t * -exp(A)) * h_{t-1} + (dt_t * u_t) B_t
    y_t = C_t . h_t + D * u_t

Grid: (B, d_inner/di_block, T/chunk); t innermost (sequential on TPU), so
the scratch state survives across chunks and resets when (b, di) advance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(u_ref, dt_ref, b_ref, c_ref, nega_ref, dskip_ref, y_ref, s_ref,
            *, chunk: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    neg_a = nega_ref[...].astype(F32)                      # (dib, st)
    dskip = dskip_ref[...].astype(F32)                     # (1, dib)

    def body(i, _):
        """Advance the SSM state one timestep within the chunk."""
        u = u_ref[0, i].astype(F32)                        # (dib,)
        dt = dt_ref[0, i].astype(F32)
        b = b_ref[0, i].astype(F32)                        # (st,)
        c = c_ref[0, i].astype(F32)
        da = jnp.exp(dt[:, None] * neg_a)                  # (dib, st)
        s = da * s_ref[...] + (dt * u)[:, None] * b[None, :]
        s_ref[...] = s
        y = s @ c + dskip[0] * u                           # (dib,)
        y_ref[0, i] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "di_block", "interpret"))
def mamba_scan_pallas(u, dt, bmat, cmat, a_log, d_skip, *, chunk: int = 64,
                      di_block: int = 512, interpret: bool = False):
    """u,dt (B,T,di); bmat,cmat (B,T,st); a_log (di,st); d_skip (di,).

    Returns y (B,T,di) f32.  (Final-state output is not needed at training
    time; serving uses the XLA step path.)
    """
    B, T, di = u.shape
    st = a_log.shape[-1]
    di_block = min(di_block, di)
    assert T % chunk == 0 and di % di_block == 0
    neg_a = -jnp.exp(a_log.astype(F32))
    grid = (B, di // di_block, T // chunk)

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk, di_block), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk, st), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, st), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((di_block, st), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, di_block), lambda b, d, t: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block), lambda b, d, t: (b, t, d)),
        scratch_shapes=[pltpu.VMEM((di_block, st), F32)],
        out_shape=jax.ShapeDtypeStruct((B, T, di), F32),
        interpret=interpret,
    )(u, dt, bmat, cmat, neg_a, d_skip.reshape(1, di))
    return y
