"""RWKV6 (Finch) WKV recurrence — Pallas TPU kernel.

Per head (key dim = value dim = D), with data-dependent decay w_t in (0,1):

    y_t        = r_t · (S_t + diag(u) k_t v_t^T)
    S_{t+1}    = diag(w_t) S_t + k_t v_t^T

The kernel carries the (D, D) state in VMEM scratch across a sequential grid
over time chunks — the state never round-trips HBM, which is the TPU analogue
of the CUDA implementations that keep state in registers/shared memory.

Grid: (B*H, T/chunk); dim 0 outermost so the state reset at chunk==0
coincides with a new (batch, head) pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                       # (D,)

    def body(t, _):
        """One WKV recurrence step over the (D, D) state in VMEM."""
        r = r_ref[0, t].astype(jnp.float32)                # (D,)
        k = k_ref[0, t].astype(jnp.float32)
        v = v_ref[0, t].astype(jnp.float32)
        w = w_ref[0, t].astype(jnp.float32)
        s = s_ref[...]                                     # (D, D) key x value
        # y = r @ S + (sum_dk r*u*k) * v
        y = r @ s + jnp.sum(r * u * k) * v
        o_ref[0, t] = y.astype(o_ref.dtype)
        s_ref[...] = w[:, None] * s + k[:, None] * v[None, :]
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: (B, T, H, D); u: (H, D) -> (B, T, H, D)."""
    B, T, H, D = r.shape
    assert T % chunk == 0, f"T={T} not divisible by chunk={chunk}"

    def flat(x):
        """(B,T,H,D) -> (B*H, T, D)."""
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, D)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    grid = (B * H, T // chunk)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, D), lambda bh, c: (bh % H, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B * H, T, D), r.dtype),
        interpret=interpret,
    )(rf, kf, vf, wf, u)
    return out.reshape(B, H, T, D).transpose(0, 2, 1, 3)
