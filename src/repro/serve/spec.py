"""Speculative decoding: draft/verify serving with lossless acceptance.

Per decode round, a cheap DRAFT proposes up to k candidate tokens per
slot; the target model scores all of them (plus the slot's pending last
token) in ONE paged forward (`models/decode.verify_step`) — amortizing k
tokens' worth of KV-cache traffic into a single read of the pool — and an
ACCEPTANCE rule turns the target's k+1 logit rows into between 1 and k+1
emitted tokens:

* greedy (temperature 0) — accept a candidate iff it equals the target's
  argmax at its position; on the first mismatch emit the argmax instead.
  Every emitted token is the argmax the sequential loop would have
  produced, so greedy speculative decode is TOKEN-IDENTICAL to vanilla
  greedy decode (tests/test_spec.py).
* sampled — both providers draft greedily, i.e. the draft distribution is
  a point mass q = delta(d), so the standard rejection rule reduces to:
  accept d with probability p(d) under the TRUNCATED target distribution
  (`sampling.truncated_probs` — the exact distribution the vanilla
  sampler draws from); on rejection sample from the residual
  norm(max(p - q, 0)) = p with d's mass removed.  By the residual-
  sampling identity P(emit = x) = p(x)·[x = d] + (1 - p(d))·res(x) =
  p(x): every emitted token is distributed exactly as the vanilla
  sampler's — speculation changes latency, never the distribution.

Providers implement the `DraftProvider` protocol:

* `NGramDraft` — prompt-lookup drafting: match the longest recent n-gram
  of the slot's history (prompt + emitted tokens) against an earlier
  occurrence and propose its continuation.  Model-free, zero FLOPs,
  works untrained; pays off on self-repetitive outputs (summaries
  quoting the document, code, greedy cycles).
* `ModelDraft` — a small BigBird draft model (e.g.
  configs/bigbird_draft.py) with its own slot-contiguous KV cache,
  drafting k greedy tokens in a batched loop.  Draft-side rollback is
  free: rejected positions are simply re-written on the next propose
  (contiguous cache reads mask strictly by position).

Target-side rollback lives in `serve/batching.PagePool.rollback`:
verify's window writes may lazily map reserved pages past the accepted
region; pages left holding only rejected candidates are unmapped and
returned to the free list, re-crediting the reservation — shared
copy-on-write prefix pages sit strictly below the prompt end and are
never touched (DESIGN.md §Speculative decoding).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as Dec
from repro.serve import sampling as Smp
from repro.serve.batching import pow2_bucket
from repro.serve.sampling import SamplingSpec


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Engine-level speculative-decoding policy.

    `k` draft tokens are proposed and verified per round; `provider`
    selects the draft source ("ngram" needs nothing, "model" needs a
    draft ModelConfig + params with the target's vocab)."""
    k: int = 4
    provider: str = "ngram"            # "ngram" | "model"
    ngram_max: int = 3                 # longest suffix n-gram to match
    ngram_min: int = 1
    draft_cfg: object = None           # ModelConfig (provider="model")
    draft_params: object = None

    def __post_init__(self):
        assert self.k >= 1
        assert self.provider in ("ngram", "model"), self.provider
        assert 1 <= self.ngram_min <= self.ngram_max


class DraftProvider(Protocol):
    """Per-slot draft lifecycle the Engine drives.

    The contract that keeps serving bit-identical under batching: a
    slot's proposals may depend only on that slot's own history (prompt
    + emitted tokens), never on co-residents or slot index."""

    def admit(self, slot: int, prompt: np.ndarray) -> None: ...

    def observe(self, slot: int, tokens: list) -> None:
        """Tokens the target emitted (accepted drafts + the corrected /
        bonus token) — the slot's history advances by exactly these."""
        ...

    def propose(self, active: list, last: np.ndarray,
                budgets: np.ndarray) -> tuple:
        """Draft for every active slot.  `last` (capacity,) int32 — each
        slot's pending last token; `budgets` (capacity,) int32 — max
        usable draft length this round.  Returns (drafts (capacity, k)
        int32, lens (capacity,) int32) with lens[i] <= budgets[i]."""
        ...

    def evict(self, slot: int) -> None: ...


class NGramDraft:
    """Prompt-lookup drafting (model-free).

    Propose the continuation of the most recent earlier occurrence of
    the history's longest suffix n-gram, longest n first."""

    def __init__(self, k: int, max_n: int = 3, min_n: int = 1):
        self.k, self.max_n, self.min_n = k, max_n, min_n
        self._hist: dict = {}          # slot -> list of ints

    def admit(self, slot: int, prompt: np.ndarray) -> None:
        self._hist[slot] = [int(t) for t in prompt]

    def observe(self, slot: int, tokens: list) -> None:
        self._hist[slot].extend(int(t) for t in tokens)

    def evict(self, slot: int) -> None:
        self._hist.pop(slot, None)

    def _lookup(self, hist: list, budget: int) -> list:
        h = np.asarray(hist, np.int64)
        L = h.size
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            suffix = h[L - n:]
            # candidate starts of an earlier occurrence (suffix excluded)
            windows = np.lib.stride_tricks.sliding_window_view(
                h[:L - 1], n) if L - 1 >= n else np.empty((0, n), np.int64)
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            if hits.size:
                start = int(hits[-1]) + n        # most recent occurrence
                cont = h[start:start + budget]
                if cont.size:
                    return [int(t) for t in cont]
        return []

    def propose(self, active, last, budgets):
        cap = last.shape[0]
        drafts = np.zeros((cap, self.k), np.int32)
        lens = np.zeros((cap,), np.int32)
        for i in active:
            if budgets[i] <= 0:
                continue
            # the history already ends with the pending last token (the
            # engine observes every emitted batch before the next round)
            cont = self._lookup(self._hist[i], int(budgets[i]))
            drafts[i, :len(cont)] = cont
            lens[i] = len(cont)
        return drafts, lens


class ModelDraft:
    """Draft with a small BigBird model over its own slot-contiguous cache.

    The draft follows each slot's accepted stream: `admit` prefills the
    prompt into the slot's cache row, `propose` runs k greedy decode
    steps batched over all slots (idle rows write their pinned garbage
    position, exactly like the main engine's batched step), and
    `observe` advances the write position by the emitted count — the
    contiguous layout makes rollback implicit, since positions past the
    write cursor are never read (strict <= pos masks) and are simply
    re-written next round."""

    def __init__(self, cfg, params, capacity: int, max_len: int,
                 vocab_size: int, k: int):
        assert cfg.kind == "lm" and all(
            ls.kind == "attn" for ls in cfg.layer_pattern), \
            "draft model must be an attention-only LM"
        assert all(cfg.attn_spec(ls).causal for ls in cfg.layer_pattern), \
            "draft model must be causal"
        assert cfg.vocab_size == vocab_size, \
            f"draft vocab {cfg.vocab_size} != target vocab {vocab_size}"
        assert not (cfg.scan_layers and cfg.repeats > 1), \
            "scanned draft stacks are not supported"
        self.cfg, self.params, self.k = cfg, params, k
        self.capacity, self.max_len = capacity, max_len
        self.cache = Dec.cache_spec(cfg, capacity, max_len, abstract=False)
        self.pos = np.full((capacity,), max_len - 1, np.int64)
        self._prefill = jax.jit(
            lambda p, t, li: Dec.prefill(p, cfg, {"tokens": t}, max_len,
                                         last_index=li))
        self._scatter = jax.jit(
            lambda c, one, slot: jax.tree.map(
                lambda cl, ol: cl.at[slot].set(ol[0].astype(cl.dtype)),
                c, one),
            donate_argnums=(0,))
        self._propose = jax.jit(self._propose_impl, donate_argnums=(1,))

    def _propose_impl(self, params, cache, tok, pos):
        # k+1 steps for k proposals: the final step ingests d_k's K/V
        # (emitting nothing), so a fully-accepted round leaves no hole in
        # the draft cache — without it the draft diverges right after its
        # best rounds.  Rejected positions are simply re-written later.
        outs = []
        for t in range(self.k + 1):
            logits, cache = Dec.decode_step(params, self.cfg, cache,
                                            tok, pos + t)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            if t < self.k:
                outs.append(tok)
        return jnp.concatenate(outs, axis=1), cache

    def admit(self, slot: int, prompt: np.ndarray) -> None:
        L = int(prompt.size)
        b = pow2_bucket(L, self.max_len)   # the Engine's prompt bucketing
        toks = np.zeros((1, b), np.int32)
        toks[0, :L] = prompt
        _, one = self._prefill(self.params, jnp.asarray(toks),
                               jnp.asarray([L - 1], jnp.int32))
        self.cache = self._scatter(self.cache, one,
                                   jnp.asarray(slot, jnp.int32))
        # observe() advances by every emitted batch including the very
        # first (prefill-sampled) token, which the draft has NOT ingested
        # — start one short so the first propose writes it at position L
        self.pos[slot] = L - 1

    def observe(self, slot: int, tokens: list) -> None:
        self.pos[slot] += len(tokens)

    def evict(self, slot: int) -> None:
        self.pos[slot] = self.max_len - 1

    def propose(self, active, last, budgets):
        pos = np.full((self.capacity,), self.max_len - 1, np.int64)
        for i in active:
            pos[i] = self.pos[i]
        drafts, self.cache = self._propose(
            self.params, self.cache, jnp.asarray(last, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        lens = np.zeros((self.capacity,), np.int32)
        for i in active:
            lens[i] = min(self.k, int(budgets[i]))
        return np.asarray(drafts), lens


def make_provider(spec: SpecConfig, cfg, capacity: int,
                  max_len: int) -> DraftProvider:
    if spec.provider == "ngram":
        return NGramDraft(spec.k, spec.ngram_max, spec.ngram_min)
    assert spec.draft_cfg is not None and spec.draft_params is not None, \
        "provider='model' needs SpecConfig.draft_cfg + draft_params"
    return ModelDraft(spec.draft_cfg, spec.draft_params, capacity,
                      max_len, cfg.vocab_size, spec.k)


def accept_greedy(argmax_row: np.ndarray, draft: np.ndarray) -> tuple:
    """Greedy acceptance needs only the target's per-position argmaxes
    (argmax_row (n+1,) int) — the Engine exploits this to keep the full
    (B, T, V) logits on device for all-greedy batches.  Accept d_t while
    it equals the argmax after position t; on the first mismatch emit the
    argmax instead; after n accepts emit the bonus argmax."""
    n = len(draft)
    out = []
    for t in range(n):
        g = int(argmax_row[t])
        out.append(g)
        if g != int(draft[t]):
            return out, t
    out.append(int(argmax_row[n]))
    return out, n


def accept(logits: np.ndarray, draft: np.ndarray,
           sampling: SamplingSpec,
           rng: Optional[np.random.Generator]) -> tuple:
    """Turn a verify window's target logits into emitted tokens.

    logits (n+1, V) f32 — row t is the target's next-token distribution
    after the candidate at window offset t; draft (n,) int32.  Returns
    (emitted tokens list — between 1 and n+1 long, accepted draft count).

    Greedy is exact-match; sampling uses residual rejection against the
    truncated target distribution (module docstring has the identity)."""
    n = len(draft)
    out = []
    if sampling.temperature <= 0.0:
        return accept_greedy(np.argmax(logits, axis=-1), draft)
    for t in range(n):
        p = Smp.truncated_probs(logits[t], sampling)
        d = int(draft[t])
        if rng.random() <= p[d]:
            out.append(d)
            continue
        res = p.copy()
        res[d] = 0.0
        tot = res.sum()
        if tot <= 0.0:                 # p was a point mass on d: accept
            out.append(d)
            continue
        out.append(int(rng.choice(p.size, p=res / tot)))
        return out, t
    p = Smp.truncated_probs(logits[n], sampling)
    out.append(int(rng.choice(p.size, p=p)))
    return out, n


def accept_rng(sampling: SamplingSpec, generated: int) -> np.random.Generator:
    """The acceptance RNG for one verify round: a function of the
    request's seed and its own emitted-token count only, so a request's
    sampled stream is independent of co-residents and slot index (the
    same isolation contract as the device sampler's key folding).  The
    64-bit mask only makes the seed non-negative for SeedSequence —
    distinct request seeds keep distinct acceptance streams."""
    return np.random.default_rng([0x5BEC,
                                  sampling.seed & 0xFFFFFFFFFFFFFFFF,
                                  generated])
