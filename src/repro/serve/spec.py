"""Speculative decoding: draft/verify serving with lossless acceptance.

Per decode round, a cheap DRAFT proposes up to k candidate tokens per
slot; the target model scores all of them (plus the slot's pending last
token) in ONE paged forward (`models/decode.verify_step`) — amortizing k
tokens' worth of KV-cache traffic into a single read of the pool — and an
ACCEPTANCE rule turns the target's k+1 logit rows into between 1 and k+1
emitted tokens:

* greedy (temperature 0) — accept a candidate iff it equals the target's
  argmax at its position; on the first mismatch emit the argmax instead.
  Every emitted token is the argmax the sequential loop would have
  produced, so greedy speculative decode is TOKEN-IDENTICAL to vanilla
  greedy decode (tests/test_spec.py).
* sampled — both providers draft greedily, i.e. the draft distribution is
  a point mass q = delta(d), so the standard rejection rule reduces to:
  accept d with probability p(d) under the TRUNCATED target distribution
  (`sampling.truncated_probs` — the exact distribution the vanilla
  sampler draws from); on rejection sample from the residual
  norm(max(p - q, 0)) = p with d's mass removed.  By the residual-
  sampling identity P(emit = x) = p(x)·[x = d] + (1 - p(d))·res(x) =
  p(x): every emitted token is distributed exactly as the vanilla
  sampler's — speculation changes latency, never the distribution.

Providers implement the `DraftProvider` protocol:

* `NGramDraft` — prompt-lookup drafting: match the longest recent n-gram
  of the slot's history (prompt + emitted tokens) against an earlier
  occurrence and propose its continuation.  Model-free, zero FLOPs,
  works untrained; pays off on self-repetitive outputs (summaries
  quoting the document, code, greedy cycles).
* `ModelDraft` — a small BigBird draft model (e.g.
  configs/bigbird_draft.py) with its own slot-contiguous KV cache,
  drafting k greedy tokens in a batched loop.  Draft-side rollback is
  free: rejected positions are simply re-written on the next propose
  (contiguous cache reads mask strictly by position).
* `TreeDraft` — the same draft model proposing a token TREE per round
  (SpecInfer/Medusa-style): `SpecConfig.fanout[d-1]` candidates at
  depth d, all children of the depth-(d-1) spine node, scored together
  by `models/decode.verify_tree_step` in one paged forward.  Acceptance
  (`accept_tree`) walks the tree with recursive rejection per depth —
  spine first with the draft's true proposal distribution q (sampled
  spine) or point masses (greedy spine), siblings as point masses —
  which preserves the emitted marginal exactly at every step; greedy
  tree-spec stays token-identical to vanilla greedy decode.

Target-side rollback lives in `serve/batching.PagePool.rollback`:
verify's window writes may lazily map reserved pages past the accepted
region; pages left holding only rejected candidates are unmapped and
returned to the free list, re-crediting the reservation — shared
copy-on-write prefix pages sit strictly below the prompt end and are
never touched (DESIGN.md §Speculative decoding).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as Dec
from repro.obs import metrics as Om
from repro.obs.clock import clock
from repro.serve import sampling as Smp
from repro.serve.batching import pow2_bucket
from repro.serve.sampling import SamplingSpec

# host-side wall clock a draft provider spends producing candidates per
# verify round (both linear propose() and tree propose_tree() record it)
_M_PROPOSE = Om.histogram("serve_draft_propose_seconds",
                          "Draft proposal wall-clock per verify round")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Engine-level speculative-decoding policy.

    `k` draft tokens are proposed and verified per round; `provider`
    selects the draft source ("ngram" needs nothing, "model" needs a
    draft ModelConfig + params with the target's vocab; "tree" drafts a
    token TREE from the same draft model — `fanout[d]` candidates at
    depth d+1, all children of the depth-d spine node — verified in one
    forward via `models/decode.verify_tree_step`).

    `draft_temperature > 0` makes the tree's spine SAMPLED from the
    draft's own truncated distribution (draft_top_k / draft_top_p)
    instead of greedy; acceptance stays lossless because the residual
    rule subtracts the actual proposal distribution q (module
    docstring)."""
    k: int = 4
    provider: str = "ngram"            # "ngram" | "model" | "tree"
    ngram_max: int = 3                 # longest suffix n-gram to match
    ngram_min: int = 1
    draft_cfg: object = None           # ModelConfig (provider="model"/"tree")
    draft_params: object = None
    fanout: tuple = ()                 # per-depth branching (provider="tree")
    draft_temperature: float = 0.0     # 0 -> greedy spine (point masses)
    draft_top_k: int = 0
    draft_top_p: float = 1.0

    def __post_init__(self):
        assert self.k >= 1
        assert self.provider in ("ngram", "model", "tree"), self.provider
        assert 1 <= self.ngram_min <= self.ngram_max
        if self.provider == "tree":
            if not self.fanout:
                # default caterpillar: binary branching, depth k
                object.__setattr__(self, "fanout", (2,) * self.k)
            fo = tuple(int(f) for f in self.fanout)
            object.__setattr__(self, "fanout", fo)
            assert all(f >= 1 for f in fo), fo
            assert self.draft_temperature >= 0.0


class DraftProvider(Protocol):
    """Per-slot draft lifecycle the Engine drives.

    The contract that keeps serving bit-identical under batching: a
    slot's proposals may depend only on that slot's own history (prompt
    + emitted tokens), never on co-residents or slot index."""

    def admit(self, slot: int, prompt: np.ndarray) -> None: ...

    def observe(self, slot: int, tokens: list) -> None:
        """Tokens the target emitted (accepted drafts + the corrected /
        bonus token) — the slot's history advances by exactly these."""
        ...

    def propose(self, active: list, last: np.ndarray,
                budgets: np.ndarray) -> tuple:
        """Draft for every active slot.  `last` (capacity,) int32 — each
        slot's pending last token; `budgets` (capacity,) int32 — max
        usable draft length this round.  Returns (drafts (capacity, k)
        int32, lens (capacity,) int32) with lens[i] <= budgets[i]."""
        ...

    def evict(self, slot: int) -> None: ...


class NGramDraft:
    """Prompt-lookup drafting (model-free).

    Propose the continuation of the most recent earlier occurrence of
    the history's longest suffix n-gram, longest n first."""

    def __init__(self, k: int, max_n: int = 3, min_n: int = 1):
        self.k, self.max_n, self.min_n = k, max_n, min_n
        self._hist: dict = {}          # slot -> list of ints

    def admit(self, slot: int, prompt: np.ndarray) -> None:
        self._hist[slot] = [int(t) for t in prompt]

    def observe(self, slot: int, tokens: list) -> None:
        self._hist[slot].extend(int(t) for t in tokens)

    def evict(self, slot: int) -> None:
        self._hist.pop(slot, None)

    def _lookup(self, hist: list, budget: int) -> list:
        h = np.asarray(hist, np.int64)
        L = h.size
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            suffix = h[L - n:]
            # candidate starts of an earlier occurrence (suffix excluded)
            windows = np.lib.stride_tricks.sliding_window_view(
                h[:L - 1], n) if L - 1 >= n else np.empty((0, n), np.int64)
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            if hits.size:
                start = int(hits[-1]) + n        # most recent occurrence
                cont = h[start:start + budget]
                if cont.size:
                    return [int(t) for t in cont]
        return []

    def propose(self, active, last, budgets):
        t0 = clock()
        cap = last.shape[0]
        drafts = np.zeros((cap, self.k), np.int32)
        lens = np.zeros((cap,), np.int32)
        for i in active:
            if budgets[i] <= 0:
                continue
            # the history already ends with the pending last token (the
            # engine observes every emitted batch before the next round)
            cont = self._lookup(self._hist[i], int(budgets[i]))
            drafts[i, :len(cont)] = cont
            lens[i] = len(cont)
        _M_PROPOSE.observe(clock() - t0)
        return drafts, lens


class ModelDraft:
    """Draft with a small BigBird model over its own slot-contiguous cache.

    The draft follows each slot's accepted stream: `admit` prefills the
    prompt into the slot's cache row, `propose` runs k greedy decode
    steps batched over all slots (idle rows write their pinned garbage
    position, exactly like the main engine's batched step), and
    `observe` advances the write position by the emitted count — the
    contiguous layout makes rollback implicit, since positions past the
    write cursor are never read (strict <= pos masks) and are simply
    re-written next round."""

    def __init__(self, cfg, params, capacity: int, max_len: int,
                 vocab_size: int, k: int):
        assert cfg.kind == "lm" and all(
            ls.kind == "attn" for ls in cfg.layer_pattern), \
            "draft model must be an attention-only LM"
        assert all(cfg.attn_spec(ls).causal for ls in cfg.layer_pattern), \
            "draft model must be causal"
        assert cfg.vocab_size == vocab_size, \
            f"draft vocab {cfg.vocab_size} != target vocab {vocab_size}"
        assert not (cfg.scan_layers and cfg.repeats > 1), \
            "scanned draft stacks are not supported"
        self.cfg, self.params, self.k = cfg, params, k
        self.capacity, self.max_len = capacity, max_len
        self.cache = Dec.cache_spec(cfg, capacity, max_len, abstract=False)
        self.pos = np.full((capacity,), max_len - 1, np.int64)
        self._prefill = jax.jit(
            lambda p, t, li: Dec.prefill(p, cfg, {"tokens": t}, max_len,
                                         last_index=li))
        self._scatter = jax.jit(
            lambda c, one, slot: jax.tree.map(
                lambda cl, ol: cl.at[slot].set(ol[0].astype(cl.dtype)),
                c, one),
            donate_argnums=(0,))
        self._propose = jax.jit(self._propose_impl, donate_argnums=(1,))

    def _propose_impl(self, params, cache, tok, pos):
        # k+1 steps for k proposals: the final step ingests d_k's K/V
        # (emitting nothing), so a fully-accepted round leaves no hole in
        # the draft cache — without it the draft diverges right after its
        # best rounds.  Rejected positions are simply re-written later.
        outs = []
        for t in range(self.k + 1):
            logits, cache = Dec.decode_step(params, self.cfg, cache,
                                            tok, pos + t)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            if t < self.k:
                outs.append(tok)
        return jnp.concatenate(outs, axis=1), cache

    def admit(self, slot: int, prompt: np.ndarray) -> None:
        L = int(prompt.size)
        b = pow2_bucket(L, self.max_len)   # the Engine's prompt bucketing
        toks = np.zeros((1, b), np.int32)
        toks[0, :L] = prompt
        _, one = self._prefill(self.params, jnp.asarray(toks),
                               jnp.asarray([L - 1], jnp.int32))
        self.cache = self._scatter(self.cache, one,
                                   jnp.asarray(slot, jnp.int32))
        # observe() advances by every emitted batch including the very
        # first (prefill-sampled) token, which the draft has NOT ingested
        # — start one short so the first propose writes it at position L
        self.pos[slot] = L - 1

    def observe(self, slot: int, tokens: list) -> None:
        self.pos[slot] += len(tokens)

    def evict(self, slot: int) -> None:
        self.pos[slot] = self.max_len - 1

    def propose(self, active, last, budgets):
        t0 = clock()
        pos = np.full((self.capacity,), self.max_len - 1, np.int64)
        for i in active:
            pos[i] = self.pos[i]
        drafts, self.cache = self._propose(
            self.params, self.cache, jnp.asarray(last, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        lens = np.zeros((self.capacity,), np.int32)
        for i in active:
            lens[i] = min(self.k, int(budgets[i]))
        drafts = np.asarray(drafts)
        _M_PROPOSE.observe(clock() - t0)
        return drafts, lens


def make_provider(spec: SpecConfig, cfg, capacity: int,
                  max_len: int) -> DraftProvider:
    if spec.provider == "ngram":
        return NGramDraft(spec.k, spec.ngram_max, spec.ngram_min)
    assert spec.draft_cfg is not None and spec.draft_params is not None, \
        f"provider={spec.provider!r} needs SpecConfig.draft_cfg + draft_params"
    if spec.provider == "tree":
        return TreeDraft(spec.draft_cfg, spec.draft_params, capacity,
                         max_len, cfg.vocab_size, spec.fanout,
                         spec.draft_temperature, spec.draft_top_k,
                         spec.draft_top_p)
    return ModelDraft(spec.draft_cfg, spec.draft_params, capacity,
                      max_len, cfg.vocab_size, spec.k)


def accept_greedy(argmax_row: np.ndarray, draft: np.ndarray) -> tuple:
    """Greedy acceptance needs only the target's per-position argmaxes
    (argmax_row (n+1,) int) — the Engine exploits this to keep the full
    (B, T, V) logits on device for all-greedy batches.  Accept d_t while
    it equals the argmax after position t; on the first mismatch emit the
    argmax instead; after n accepts emit the bonus argmax."""
    n = len(draft)
    out = []
    for t in range(n):
        g = int(argmax_row[t])
        out.append(g)
        if g != int(draft[t]):
            return out, t
    out.append(int(argmax_row[n]))
    return out, n


def accept(logits: np.ndarray, draft: np.ndarray,
           sampling: SamplingSpec,
           rng: Optional[np.random.Generator]) -> tuple:
    """Turn a verify window's target logits into emitted tokens.

    logits (n+1, V) f32 — row t is the target's next-token distribution
    after the candidate at window offset t; draft (n,) int32.  Returns
    (emitted tokens list — between 1 and n+1 long, accepted draft count).

    Greedy is exact-match; sampling uses residual rejection against the
    truncated target distribution (module docstring has the identity)."""
    n = len(draft)
    out = []
    if sampling.temperature <= 0.0:
        return accept_greedy(np.argmax(logits, axis=-1), draft)
    for t in range(n):
        p = Smp.truncated_probs(logits[t], sampling)
        d = int(draft[t])
        if rng.random() <= p[d]:
            out.append(d)
            continue
        res = p.copy()
        res[d] = 0.0
        tot = res.sum()
        if tot <= 0.0:                 # p was a point mass on d: accept
            out.append(d)
            continue
        out.append(int(rng.choice(p.size, p=res / tot)))
        return out, t
    p = Smp.truncated_probs(logits[n], sampling)
    out.append(int(rng.choice(p.size, p=p)))
    return out, n


def accept_rng(sampling: SamplingSpec, generated: int) -> np.random.Generator:
    """The acceptance RNG for one verify round: a function of the
    request's seed and its own emitted-token count only, so a request's
    sampled stream is independent of co-residents and slot index (the
    same isolation contract as the device sampler's key folding).  The
    64-bit mask only makes the seed non-negative for SeedSequence —
    distinct request seeds keep distinct acceptance streams."""
    return np.random.default_rng([0x5BEC,
                                  sampling.seed & 0xFFFFFFFFFFFFFFFF,
                                  generated])


# --------------------------------------------------------------------------
# token trees (SpecInfer/Medusa-style multi-candidate verification)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeTopology:
    """A STATIC caterpillar token tree shared by every slot and compiled
    into the verify graph (numpy constants, no traced operands).

    Node 0 is the root (the slot's pending last token).  Depth d
    (1-based) contributes `fanout[d-1]` candidate nodes, ALL children of
    the depth-(d-1) SPINE node; the spine node of each depth is the
    first of its group (the draft's top-1 / sampled continuation), and
    off-spine nodes are leaves.  `anc[t, j]` is node t's ancestor at
    depth j (anc[t, depths[t]] = t; entries past t's depth pad with t
    and are masked out by the verify kernel's depth test)."""
    fanout: tuple
    depths: np.ndarray                 # (T,) int32
    anc: np.ndarray                    # (T, D+1) int32
    parent: np.ndarray                 # (T,) int32, parent[0] = -1
    spine: np.ndarray                  # (D+1,) int32 node index per depth
    children: tuple                    # children[u] = node tuple, spine first

    @property
    def size(self) -> int:
        return int(self.depths.shape[0])

    @property
    def depth(self) -> int:
        return len(self.fanout)


def tree_topology(fanout) -> TreeTopology:
    fanout = tuple(int(f) for f in fanout)
    assert fanout and all(f >= 1 for f in fanout), fanout
    D = len(fanout)
    T = 1 + sum(fanout)
    depths = np.zeros((T,), np.int32)
    parent = np.full((T,), -1, np.int32)
    spine = np.zeros((D + 1,), np.int32)
    t = 1
    for d, f in enumerate(fanout, start=1):
        spine[d] = t
        for _ in range(f):
            depths[t] = d
            parent[t] = spine[d - 1]
            t += 1
    children = [[] for _ in range(T)]
    for u in range(1, T):
        children[int(parent[u])].append(u)
    anc = np.zeros((T, D + 1), np.int32)
    for u in range(T):
        anc[u] = u                     # pad; masked past depths[u]
        v = u
        for j in range(int(depths[u]), -1, -1):
            anc[u, j] = v
            v = int(parent[v]) if v else 0
    return TreeTopology(fanout, depths, anc, parent, spine,
                        tuple(tuple(c) for c in children))


class TreeDraft:
    """Draft a token TREE per round from a small model's logits.

    The spine (depth-wise top-1, or a sample from the draft's own
    truncated distribution when `temperature` > 0) is decoded
    autoregressively through the draft's slot-contiguous cache; the
    off-spine candidates at depth d are the remaining top-`fanout[d-1]`
    tokens of the SAME logits row — one draft forward per depth buys
    fanout[d-1] verified candidates.

    Cache bookkeeping differs from `ModelDraft` because the target can
    accept an OFF-spine candidate, diverging from everything the draft
    wrote past that depth.  Per slot we track `pos` (tokens whose K/V
    the draft cache holds for the slot's true history) and `_pending`
    (emitted tokens not yet ingested, always ending with the slot's
    pending last token).  `propose_tree` is one fused jit: phase 1
    ingests the padded pending tokens (the logits row after the LAST
    real pending token seeds depth 1; garbage writes past it are
    overwritten by phase 2 — guaranteed because pending is never empty),
    phase 2 runs `depth` spine steps.  `observe` advances `pos` by the
    ingested count plus the emitted/spine common prefix and re-queues
    the rest as pending — the caterpillar analogue of ModelDraft's
    "rejected positions are simply re-written next round"."""

    def __init__(self, cfg, params, capacity: int, max_len: int,
                 vocab_size: int, fanout, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0):
        assert cfg.kind == "lm" and all(
            ls.kind == "attn" for ls in cfg.layer_pattern), \
            "draft model must be an attention-only LM"
        assert all(cfg.attn_spec(ls).causal for ls in cfg.layer_pattern), \
            "draft model must be causal"
        assert cfg.vocab_size == vocab_size, \
            f"draft vocab {cfg.vocab_size} != target vocab {vocab_size}"
        assert not (cfg.scan_layers and cfg.repeats > 1), \
            "scanned draft stacks are not supported"
        self.cfg, self.params = cfg, params
        self.topo = tree_topology(fanout)
        self.fanout = self.topo.fanout
        self.depth = self.topo.depth
        self.max_f = max(self.fanout)
        self.temperature = float(temperature)
        self.top_k, self.top_p = int(top_k), float(top_p)
        self.capacity, self.max_len = capacity, max_len
        self.cache = Dec.cache_spec(cfg, capacity, max_len, abstract=False)
        self.pos = np.full((capacity,), max_len - 1, np.int64)
        self._pending: dict = {}       # slot -> [int] not yet in cache
        self._spine: dict = {}         # slot -> last proposed spine tokens
        self._ingested: dict = {}      # slot -> pending consumed last round
        self._prefill = jax.jit(
            lambda p, t, li: Dec.prefill(p, cfg, {"tokens": t}, max_len,
                                         last_index=li))
        self._scatter = jax.jit(
            lambda c, one, slot: jax.tree.map(
                lambda cl, ol: cl.at[slot].set(ol[0].astype(cl.dtype)),
                c, one),
            donate_argnums=(0,))
        self._propose = jax.jit(self._propose_impl, donate_argnums=(1,))

    def _propose_impl(self, params, cache, pend, plen, pos, dseed):
        """pend (B, depth+1) int32 padded pending tokens, plen (B,) >= 1
        real lengths, pos (B,) first pending write position, dseed (B,)
        uint32 per-slot draft seed (the request's sampling seed).
        Returns (spine (B, D), topk (B, D, max_f), draft logits
        (B, D, V) or None, cache)."""
        B = pend.shape[0]
        # phase 1 — ingest pending: step j writes pend[:, j] at pos + j.
        # Rows past plen write garbage at pos+plen..pos+depth; phase 2's
        # spine writes cover pos+plen..pos+plen+depth-1, a superset
        # because plen >= 1, and reads mask strictly by position, so no
        # garbage row is ever read before it is overwritten.
        rows = []
        for j in range(self.depth + 1):
            logits, cache = Dec.decode_step(params, self.cfg, cache,
                                            pend[:, j][:, None], pos + j)
            rows.append(logits)
        allrows = jnp.stack(rows, axis=1)                      # (B, J, V)
        logits = jnp.take_along_axis(
            allrows, (plen - 1)[:, None, None], axis=1)[:, 0]  # (B, V)
        if self.temperature > 0.0:
            # per-REQUEST draft randomness: the key stream folds the
            # request's sampling seed and the spine start position (depth
            # via fold_step_keys).  Slot-index independent, so a request
            # drafts reproducibly under any batching — but distinct
            # requests with identical histories draw INDEPENDENT spine
            # samples, which q-aware acceptance (accept_tree's
            # min(1, r/q) rule) requires: it is only lossless when the
            # spine is a fresh sample from q, not a deterministic
            # function of the history
            base = jax.random.PRNGKey(0x7BEE)
            keys = jax.vmap(
                lambda sd, s: jax.random.fold_in(
                    jax.random.fold_in(base, sd), s))(dseed, pos + plen)
            temps = jnp.full((B,), self.temperature, jnp.float32)
            tks = jnp.full((B,), self.top_k, jnp.int32)
            tps = jnp.full((B,), self.top_p, jnp.float32)
        spine, topk, qrows = [], [], []
        for d in range(self.depth):
            topk.append(jax.lax.top_k(logits, self.max_f)[1]
                        .astype(jnp.int32))
            if self.temperature > 0.0:
                qrows.append(logits)
                s = Smp.sample_tokens(logits, Smp.fold_step_keys(keys, d),
                                      temps, tks, tps)
            else:
                s = jnp.argmax(logits, axis=-1)
            s = s.astype(jnp.int32)
            spine.append(s)
            logits, cache = Dec.decode_step(params, self.cfg, cache,
                                            s[:, None], pos + plen + d)
        qout = jnp.stack(qrows, axis=1) if qrows else None
        return (jnp.stack(spine, axis=1), jnp.stack(topk, axis=1),
                qout, cache)

    def admit(self, slot: int, prompt: np.ndarray) -> None:
        L = int(prompt.size)
        b = pow2_bucket(L, self.max_len)   # the Engine's prompt bucketing
        toks = np.zeros((1, b), np.int32)
        toks[0, :L] = prompt
        _, one = self._prefill(self.params, jnp.asarray(toks),
                               jnp.asarray([L - 1], jnp.int32))
        self.cache = self._scatter(self.cache, one,
                                   jnp.asarray(slot, jnp.int32))
        # cache now holds positions 0..L-1; the first emitted batch (the
        # prefill-sampled token) arrives via observe() as pending
        self.pos[slot] = L
        self._pending[slot] = []
        self._spine.pop(slot, None)
        self._ingested.pop(slot, None)

    def observe(self, slot: int, tokens: list) -> None:
        toks = [int(t) for t in tokens]
        sp = self._spine.pop(slot, [])
        j = 0
        while j < min(len(sp), len(toks)) and toks[j] == sp[j]:
            j += 1
        self.pos[slot] += self._ingested.pop(slot, 0) + j
        self._pending[slot] = self._pending.get(slot, []) + toks[j:]

    def evict(self, slot: int) -> None:
        self.pos[slot] = self.max_len - 1
        self._pending.pop(slot, None)
        self._spine.pop(slot, None)
        self._ingested.pop(slot, None)

    def propose(self, active, last, budgets):
        raise NotImplementedError(
            "TreeDraft drafts trees; the engine calls propose_tree()")

    def propose_tree(self, active, budgets, seeds=None):
        """Returns (cand (capacity, T-1) int32 — candidate tokens for
        tree nodes 1..T-1 in node order — and draft_q: None for a greedy
        spine, else (capacity, D, V) f32 draft logits whose
        `truncated_probs` under the draft's sampling spec is the exact
        spine proposal distribution q at each depth).  `seeds` (B,)
        uint32 per-slot request seeds drive the sampled spine's key
        stream — required when temperature > 0 so each request's spine
        is an independent q-sample (accept_tree's q-aware rule is only
        lossless against fresh samples)."""
        t0 = clock()
        B, J = self.capacity, self.depth + 1
        pend = np.zeros((B, J), np.int32)
        plen = np.ones((B,), np.int32)
        pos = np.full((B,), self.max_len - 1, np.int64)
        if seeds is None:
            seeds = np.zeros((B,), np.uint32)
        for i in active:
            pl = self._pending.get(i, [])
            assert pl, "propose_tree() before the slot's first observe()"
            self._ingested[i] = len(pl)
            pend[i, :len(pl)] = pl
            plen[i] = len(pl)
            pos[i] = self.pos[i]
            self._pending[i] = []
        spine, topk, qrows, self.cache = self._propose(
            self.params, self.cache, jnp.asarray(pend),
            jnp.asarray(plen), jnp.asarray(pos, jnp.int32),
            jnp.asarray(seeds, jnp.uint32))
        spine, topk = np.asarray(spine), np.asarray(topk)
        cand = np.zeros((B, self.topo.size - 1), np.int32)
        for i in active:
            self._spine[i] = [int(t) for t in spine[i]]
            col = 0
            for d, f in enumerate(self.fanout):
                grp = [int(spine[i, d])]
                for t in topk[i, d]:
                    if len(grp) >= f:
                        break
                    if int(t) != grp[0]:
                        grp.append(int(t))
                cand[i, col:col + f] = grp[:f]
                col += f
        dq = np.asarray(qrows) if qrows is not None else None
        _M_PROPOSE.observe(clock() - t0)
        return cand, dq


def accept_tree_greedy(argmax_rows: np.ndarray, tokens: np.ndarray,
                       topo: TreeTopology, budget: int) -> tuple:
    """Walk the tree greedily: from the current node emit the target's
    argmax; if it equals a child candidate (within the depth budget),
    descend — that child IS what sequential greedy decode would have
    emitted there — else stop.  Children are scanned spine-first so a
    sampled-spine duplicate of a sibling prefers the deeper
    continuation.  Returns (emitted tokens, accepted count m, final
    accepted node index — depths[final] == m, and the root-to-final
    path is anc[final, :m+1])."""
    out, cur, m = [], 0, 0
    while True:
        g = int(argmax_rows[cur])
        out.append(g)
        nxt = None
        for c in topo.children[cur]:
            if int(topo.depths[c]) <= budget and int(tokens[c]) == g:
                nxt = c
                break
        if nxt is None:
            return out, m, cur
        cur, m = nxt, m + 1


def accept_tree(logits: np.ndarray, tokens: np.ndarray, topo: TreeTopology,
                budget: int, sampling: SamplingSpec,
                rng: Optional[np.random.Generator],
                draft_q: Optional[np.ndarray] = None) -> tuple:
    """Multi-candidate lossless acceptance over one slot's tree logits.

    logits (T, V) f32 — row t is the target's next-token distribution
    after node t's root-to-node path; tokens (T,) int32 (tokens[0] is
    the root's token, never re-emitted); draft_q None (all candidates
    are point masses) or (D, V) f64 — row d-1 is the spine's exact
    proposal distribution at depth d (`sampling.truncated_probs` of the
    draft's logits under the DRAFT's sampling spec).

    At each accepted node, recursive rejection over its children
    (spine first): candidate c with proposal distribution q_c is
    accepted w.p. min(1, r(c)/q_c(c)) against the running residual r
    (initially the truncated target p), and on rejection
    r <- norm(max(r - q_c, 0)); if every child is rejected, emit a
    sample from the final residual and stop.  Each step preserves the
    emitted marginal exactly (module docstring), so composing them down
    the tree keeps every emitted token distributed as vanilla
    sampling's — with a point-mass q this is PR 5's `accept`, and with
    one child per depth the walk reduces to the linear window.

    Returns (emitted tokens, accepted count m, final node index)."""
    if sampling.temperature <= 0.0:
        return accept_tree_greedy(np.argmax(logits, axis=-1), tokens,
                                  topo, budget)
    out, cur, m = [], 0, 0
    while True:
        kids = [c for c in topo.children[cur]
                if int(topo.depths[c]) <= budget]
        p = Smp.truncated_probs(logits[cur], sampling)
        if not kids:
            out.append(int(rng.choice(p.size, p=p)))
            return out, m, cur
        r = p.astype(np.float64)
        took = None
        for c in kids:
            d = int(tokens[c])
            is_spine = draft_q is not None and c == int(
                topo.spine[int(topo.depths[c])])
            if is_spine:
                q = draft_q[int(topo.depths[c]) - 1]
                qd = float(q[d])
                a = min(1.0, r[d] / qd) if qd > 0.0 else float(r[d] > 0.0)
                if rng.random() <= a:
                    took = c
                    break
                r = np.maximum(r - q, 0.0)
            else:
                if rng.random() <= r[d]:
                    took = c
                    break
                r = r.copy()
                r[d] = 0.0
            tot = r.sum()
            if tot <= 0.0:             # residual exhausted: accept c
                took = c
                break
            r = r / tot
        if took is None:
            out.append(int(rng.choice(r.size, p=r)))
            return out, m, cur
        out.append(int(tokens[took]))
        cur, m = took, m + 1
