"""The small serving surface: Request in, Result out.

Callers (launch/serve.py, the examples, benchmarks/serving.py) speak only
this vocabulary plus `Engine.generate` / `Engine.submit` / `Engine.step` /
`Engine.drain`.  Everything else — compiled executables, slot pools,
sampling internals — is an Engine implementation detail.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serve.sampling import SamplingSpec


@dataclasses.dataclass
class Request:
    """One generation request for the slot-batched serving path."""
    prompt: np.ndarray                     # (L,) int32 prompt tokens
    max_new_tokens: int = 32
    sampling: SamplingSpec = dataclasses.field(default_factory=SamplingSpec)
    stop_token: Optional[int] = None
    request_id: Optional[int] = None       # assigned by Engine.submit

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size >= 1, "empty prompt"
        assert self.max_new_tokens >= 1


@dataclasses.dataclass
class Result:
    """A finished request: generated tokens + serving bookkeeping."""
    request_id: int
    tokens: List[int]                      # generated tokens (incl. stop)
    prompt_len: int
    finish_reason: str                     # "stop" | "length" | "aborted"
                                           # | "deadline_exceeded" | "shed"
    ttft_steps: int = 0                    # engine steps from submit to 1st tok
    pages_used: int = 0                    # KV pages this request mapped
    shared_prefix_pages: int = 0           # of which reused from a co-resident
    ttft_s: float = 0.0                    # wall-clock submit -> first token
    tpot_s: float = 0.0                    # wall-clock per output token after
    #                                        the first (the spec-decode win);
    #                                        0.0 when the engine never
    #                                        observed a first token (aborted
    #                                        or shed before TTFT)
    queue_wait_s: float = 0.0              # wall-clock submit -> admission
    #                                        (the slice of ttft_s spent
    #                                        queued; obs records it into
    #                                        serve_queue_wait_seconds)
    draft_proposed: int = 0                # speculative candidates verified
    draft_accepted: int = 0                # of which the target accepted
    verify_steps: int = 0                  # draft/verify rounds run

    @property
    def acceptance_rate(self) -> float:
        """Accepted fraction of speculative draft tokens (0 when the
        request never ran a draft/verify round)."""
        return (self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0)


@dataclasses.dataclass
class PoolStats:
    """Page-pool occupancy snapshot (`Engine.stats()`).

    Aggregates cover the whole pool; the `*_per_shard` fields break the
    partitioned pool down along the mesh's data axis (single-entry lists
    when serving unsharded)."""
    num_pages: int                         # usable pages (dump pages excluded)
    page_size: int                         # tokens per page (= pattern block)
    pages_in_use: int
    peak_pages_in_use: int
    prefix_hits: int                       # admits that reused >= 1 page
    prefix_pages_shared: int               # cumulative pages not re-admitted
    requests_admitted: int
    kv_bytes_per_page: int                 # KV bytes one page holds (all layers)
    data_shards: int = 1                   # data-axis partitions of the pool
    pages_per_shard: int = 0               # usable pages per data shard
    pages_reserved: int = 0                # promised to residents, unmapped
    pages_in_use_per_shard: List[int] = dataclasses.field(default_factory=list)
    peak_pages_per_shard: List[int] = dataclasses.field(default_factory=list)
    kv_bytes_per_shard: int = 0            # physical KV bytes one shard holds
    pages_host: int = 0                    # pages parked in the host swap tier
    swap_in: int = 0                       # cumulative swap-in events
    swap_out: int = 0                      # cumulative swap-out events


@dataclasses.dataclass
class GenerateOutput:
    """Batched `Engine.generate` output."""
    tokens: np.ndarray                     # (B, max_new) int32, 0-padded
    lengths: np.ndarray                    # (B,) generated count incl. stop

    def sequences(self) -> List[List[int]]:
        return [self.tokens[i, :self.lengths[i]].tolist()
                for i in range(self.tokens.shape[0])]
