"""Async streaming front-end over the synchronous serving Engine.

`AsyncEngine` turns the Engine's submit/step/drain batch interface into
per-request asyncio token streams:

    front = AsyncEngine(Engine(cfg, params, ...))
    session = await front.submit(prompt, max_new_tokens=64)
    async for tok in session:          # tokens as the engine emits them
        ...
    result = await session.result()    # the same typed Result drain() returns

One background task owns the engine: it admits queued requests, runs
`engine.step()` on an executor thread (the event loop stays responsive
while the device works), and routes each step's new tokens to their
sessions.  Nothing else ever touches the engine — `submit()` and
`cancel()` only record intents that the loop applies between steps, so
the engine sees strictly serialized calls.

Scheduling semantics (DESIGN.md §Async front-end):

  * admission — a priority queue in front of the engine's FIFO: higher
    `priority` admits first; ties admit in arrival order.  The frontend
    feeds the engine's queue only up to the free-slot budget, so priority
    order is decided here, not by engine head-of-line.
  * deadlines — `deadline_s` bounds time-to-first-token.  A request that
    expires while queued (or resident but before its first streamed
    token) finishes with `finish_reason="deadline_exceeded"`; its pages
    and reservations are released through `Engine.abort`.  Once a token
    has streamed the deadline no longer applies — UNLESS the request is
    later swapped out to the host tier (`Engine(host_swap=True)`): a
    swapped resident's next token may be arbitrarily delayed, so the
    deadline re-arms for exactly as long as it stays swapped
    (`Engine.swapped_requests`), releasing its host buffer on expiry.
  * load shedding — the admission queue holds at most `max_queue`
    requests.  A submit against a full queue sheds the lowest-priority
    queued request if the newcomer outranks it, else the newcomer —
    either way the victim finishes immediately with
    `finish_reason="shed"`.  `wait=True` opts into backpressure instead:
    the submit coroutine suspends until space frees.
  * bit-identity — streams carry exactly the tokens the synchronous
    `Engine.drain` path produces (per-slot PRNG keys make every stream
    independent of co-residents and of dispatch depth), so greedy
    streamed output is token-identical to the batch path.
"""

from __future__ import annotations

import asyncio
import dataclasses
import heapq
from typing import List, Optional

import numpy as np

from repro.obs import metrics as Om
from repro.obs.clock import clock
from repro.serve.api import Request, Result
from repro.serve.engine import Engine
from repro.serve.sampling import SamplingSpec

_END = object()  # stream terminator sentinel

# admission-policy outcomes (every timestamp here reads obs.clock so
# deadline/shed tests can install a FakeClock instead of sleeping)
_M_SHED = Om.counter("serve_shed_total",
                     "Requests shed by the bounded admission queue")
_M_DEADLINE = Om.counter("serve_deadline_expired_total",
                         "Requests expired by their TTFT deadline")


def _empty_result(sess: "StreamSession", reason: str) -> Result:
    """A terminal Result for a request that never produced tokens."""
    return Result(
        request_id=sess.request_id,
        tokens=[],
        prompt_len=int(sess.request.prompt.size),
        finish_reason=reason,
    )


class StreamSession:
    """One submitted request: an async token iterator plus its Result.

    `async for tok in session` yields generated token ids in order; the
    loop ends when the request finishes (stop/length/abort/deadline/shed).
    `await session.result()` returns the typed Result.  `cancel()`
    requests cooperative cancellation."""

    def __init__(
        self,
        frontend: "AsyncEngine",
        request: Request,
        priority: int,
        deadline_s: Optional[float],
        seq: int,
    ):
        self._frontend = frontend
        self.request = request
        self.request_id = request.request_id
        self.priority = priority
        self.seq = seq
        self.submit_time = clock()
        self.deadline = (
            self.submit_time + deadline_s if deadline_s is not None else None
        )
        self._tokens: asyncio.Queue = asyncio.Queue()
        self._result: asyncio.Future = asyncio.get_running_loop().create_future()
        self._emitted = 0

    def __aiter__(self):
        return self

    async def __anext__(self):
        tok = await self._tokens.get()
        if tok is _END:
            raise StopAsyncIteration
        return tok

    async def result(self) -> Result:
        return await self._result

    @property
    def done(self) -> bool:
        return self._result.done()

    def cancel(self):
        """Cancel the request: the stream ends after already-computed
        tokens and result() resolves with finish_reason="aborted"; pages,
        CoW refcounts and reservations release at the next step boundary."""
        self._frontend._cancel(self)

    # -- frontend internals (event-loop thread only) -----------------------

    def _emit(self, toks):
        for t in toks:
            self._tokens.put_nowait(int(t))
        self._emitted += len(toks)

    def _finish(self, result: Result):
        if self._result.done():
            return
        n = self._emitted
        self._emit(result.tokens[n:])
        self._result.set_result(result)
        self._tokens.put_nowait(_END)


class AsyncEngine:
    """Asyncio front-end: priority/deadline admission + token streaming
    over one `Engine` (see the module docstring for the semantics)."""

    def __init__(self, engine: Engine, *, max_queue: int = 64):
        assert engine.pool is not None, (
            "AsyncEngine streams through the continuous-batching path; "
            "encdec/patch configs serve through Engine.generate()"
        )
        self._engine = engine
        self._max_queue = max_queue
        self._heap: list = []  # (-priority, seq, session)
        self._seq = 0
        self._queued: dict = {}  # request_id -> session, pre-admission
        self._live: dict = {}  # request_id -> session, in the engine
        self._aborts: List[int] = []  # cancel intents, applied between steps
        self._wake = asyncio.Event()
        self._space = asyncio.Event()
        self._space.set()
        self._closed = False
        self._task: Optional[asyncio.Task] = None

    # -- public API --------------------------------------------------------

    async def submit(
        self,
        prompt,
        max_new_tokens: int,
        *,
        sampling: SamplingSpec = SamplingSpec(),
        stop_token: Optional[int] = None,
        priority: int = 0,
        deadline_s: Optional[float] = None,
        wait: bool = False,
    ) -> StreamSession:
        """Submit a prompt for streamed generation.

        priority — higher admits first (ties: arrival order);
        deadline_s — TTFT budget in seconds (see module docstring);
        wait — backpressure instead of shedding when the queue is full."""
        if self._closed:
            raise RuntimeError("AsyncEngine is closed")
        if self._task is None or self._task.done():
            self._task = asyncio.ensure_future(self._run())
        if wait:
            while len(self._queued) >= self._max_queue:
                self._space.clear()
                await self._space.wait()
                if self._closed:
                    raise RuntimeError("AsyncEngine is closed")
        rid = self._engine._next_id
        self._engine._next_id += 1
        request = Request(
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=max_new_tokens,
            sampling=sampling,
            stop_token=stop_token,
            request_id=rid,
        )
        session = StreamSession(self, request, priority, deadline_s, self._seq)
        self._seq += 1
        if len(self._queued) >= self._max_queue:
            # shed the lowest-priority queued request if the newcomer
            # outranks it (ties favor the incumbent), else the newcomer
            worst = min(self._queued.values(), key=lambda s: (s.priority, -s.seq))
            victim = worst if worst.priority < priority else session
            if victim is not session:
                del self._queued[victim.request_id]
            _M_SHED.inc()
            victim._finish(_empty_result(victim, "shed"))
            if victim is session:
                return session
        self._queued[rid] = session
        heapq.heappush(self._heap, (-priority, session.seq, session))
        self._update_space()
        self._wake.set()
        return session

    async def close(self, drain: bool = True):
        """Stop accepting submissions.  drain=True (default) waits for
        every queued and resident request to finish; drain=False aborts
        them all first."""
        self._closed = True
        if not drain:
            for rid, sess in list(self._queued.items()):
                del self._queued[rid]
                sess._finish(_empty_result(sess, "aborted"))
            self._aborts.extend(list(self._live))
        self._wake.set()
        self._space.set()
        if self._task is not None:
            await self._task
            self._task = None

    # -- run loop (the only engine caller) ---------------------------------

    async def _run(self):
        loop = asyncio.get_running_loop()
        eng = self._engine
        while True:
            self._apply_aborts()
            self._expire(clock())
            self._admit()
            busy = bool(
                eng._queue
                or eng._inflight
                or eng._pending_finished
                or eng.pool.active_slots()
            )
            if not busy:
                if self._closed and not self._queued:
                    return
                self._wake.clear()
                # sleep until new work — or the next queued TTFT deadline,
                # which must fire even while the engine idles
                deadlines = [
                    s.deadline for s in self._queued.values() if s.deadline is not None
                ]
                timeout = None
                if deadlines:
                    timeout = max(0.0, min(deadlines) - clock())
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout)
                except asyncio.TimeoutError:
                    pass
                continue
            results = await loop.run_in_executor(None, eng.step)
            self._route(results)

    def _apply_aborts(self):
        while self._aborts:
            rid = self._aborts.pop()
            sess = self._live.pop(rid, None)
            if sess is None:
                continue  # finished before the intent applied
            result = self._engine.abort(rid)
            if result is None:
                result = _empty_result(sess, "aborted")
            sess._finish(result)

    def _expire(self, now: float):
        for rid, sess in list(self._queued.items()):
            if sess.deadline is not None and now >= sess.deadline:
                del self._queued[rid]
                _M_DEADLINE.inc()
                sess._finish(_empty_result(sess, "deadline_exceeded"))
        self._update_space()
        # deadline covers TTFT — and re-arms while a resident sits in the
        # host swap tier (its next token is not schedulable until resume)
        swapped = set(self._engine.swapped_requests())
        for rid, sess in list(self._live.items()):
            if (
                sess.deadline is not None
                and (sess._emitted == 0 or rid in swapped)
                and now >= sess.deadline
            ):
                del self._live[rid]
                _M_DEADLINE.inc()
                result = self._engine.abort(rid)
                if result is not None:
                    result = dataclasses.replace(
                        result, finish_reason="deadline_exceeded"
                    )
                else:
                    result = _empty_result(sess, "deadline_exceeded")
                sess._finish(result)

    def _admit(self):
        """Feed the engine's FIFO best-priority-first, up to the free-slot
        budget (at least one, so head-of-line page pressure is the
        engine's to resolve — admission ORDER stays the frontend's)."""
        eng = self._engine
        budget = max(1, len(eng.pool.free_slots())) - len(eng._queue)
        while self._heap and budget > 0:
            _, _, sess = heapq.heappop(self._heap)
            if sess.request_id not in self._queued:
                continue  # shed or cancelled while queued
            del self._queued[sess.request_id]
            eng.submit(sess.request, submit_time=sess.submit_time)
            self._live[sess.request_id] = sess
            budget -= 1
        self._update_space()

    def _route(self, results: List[Result]):
        eng = self._engine
        for r in results:
            sess = self._live.pop(r.request_id, None)
            if sess is not None:
                sess._finish(r)
        # stream the step's new tokens from still-resident slots
        for slot, meta in list(eng._slot_meta.items()):
            sess = self._live.get(meta[0].request_id)
            if sess is None:
                continue
            s = eng.pool.slots[slot]
            n = sess._emitted
            if s is not None and len(s.tokens) > n:
                sess._emit(s.tokens[n:])

    def _cancel(self, sess: StreamSession):
        rid = sess.request_id
        if sess.done:
            return
        if rid in self._queued:
            del self._queued[rid]
            sess._finish(_empty_result(sess, "aborted"))
            self._update_space()
            return
        self._aborts.append(rid)
        self._wake.set()

    def _update_space(self):
        if len(self._queued) < self._max_queue:
            self._space.set()
