"""Mesh-parallel serving executables (DESIGN.md §Mesh-parallel serving).

The Engine's sharded path builds its two hot executables here, each a
`jax.jit(shard_map(...))` over a `(data, model)` mesh:

* `slot_step_fn` — the batched decode step.  Slots, positions, page
  tables, sampling arrays, and the paged K/V page dim split along
  `data`; kv heads split along `model`.  Inside the per-shard body,
  `models/decode.decode_step(model_axis="model")` computes attention on
  the shard's local head slice and all-gathers only the per-head outputs;
  everything else is replicated full-width math, so the sharded step is
  bit-identical to the replicated one.
* `chunk_fn` — one prefill chunk, compiled per (start, bucket).  Every
  data shard runs the same chunk tokens (SPMD), but only the owning
  shard's row carries live page-table entries; the other rows read and
  write their local dump page, so their compute is discarded by
  construction.

Host metadata (free lists, refcounts, admission) stays in
`serve/batching.PagePool`, partitioned per data shard; this module only
owns device placement and the shard_map wrappers.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as Sh
from repro.models import decode as Dec
from repro.serve import sampling as Smp

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.5 keeps it in the experimental namespace
    from jax.experimental.shard_map import shard_map

MODEL_AXIS = "model"
DATA_AXIS = "data"


def make_mesh(data: int, model: int):
    """A (data, model) serving mesh over data*model local devices."""
    need, have = data * model, len(jax.devices())
    if need > have:
        raise ValueError(f"mesh {data}x{model} needs {need} devices, have {have}")
    return jax.make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))


def parse_mesh(spec: str):
    """Parse a 'DxM' --mesh flag ('2x2') into a (data, model) mesh."""
    try:
        d, m = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh expects DxM (e.g. 2x2), got {spec!r}") from None
    return make_mesh(d, m)


def cache_pspecs(cfg, capacity: int, max_len: int, num_pages: int,
                 kv_dtype=None):
    """PartitionSpec tree for the paged serving cache."""
    return Sh.serving_cache_pspecs(cfg, capacity, max_len, num_pages,
                                   kv_dtype=kv_dtype)


def place_cache(cache, mesh, pspecs):
    """Commit the pool's cache tree to its mesh sharding."""
    return jax.tree.map(
        lambda x, ps: jax.device_put(x, NamedSharding(mesh, ps)), cache, pspecs
    )


def replicate(tree, mesh):
    """Commit a tree (params) fully replicated over the mesh."""
    return jax.tree.map(lambda x: jax.device_put(x, NamedSharding(mesh, P())), tree)


def _samp_specs():
    return {
        "temperature": P(DATA_AXIS),
        "top_k": P(DATA_AXIS),
        "top_p": P(DATA_AXIS),
        "keys": P(DATA_AXIS, None),
    }


def slot_step_fn(cfg, mesh, cache_ps):
    """The sharded batched decode step: (params, cache, tok, pos, tables,
    samp, step_keys) -> (next tokens, cache)."""

    def body(params, cache, tok, pos, pt, samp, step_keys):
        logits, cache = Dec.decode_step(
            params, cfg, cache, tok, pos, page_tables=pt, model_axis=MODEL_AXIS
        )
        nxt = Smp.sample_tokens(
            logits, step_keys, samp["temperature"], samp["top_k"], samp["top_p"]
        )
        return nxt, cache

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),
            cache_ps,
            P(DATA_AXIS, None),
            P(DATA_AXIS),
            P(DATA_AXIS, None),
            _samp_specs(),
            P(DATA_AXIS, None),
        ),
        out_specs=(P(DATA_AXIS), cache_ps),
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(1,))


def verify_fn(cfg, mesh, cache_ps):
    """The sharded speculative-verify step: (params, cache, tok (B, T),
    pos, n_valid, tables) -> (logits (B, T, V), cache).  Slots split over
    `data` exactly like the decode step; inside the per-shard body the
    paged K/V leaves carry the model shard's local kv heads, so the
    verify window runs tensor-parallel with the same head-slice +
    all-gather contract — bit-identical to the replicated verify, which
    is itself bit-identical to sequential decode."""

    def body(params, cache, tok, pos, nv, pt):
        return Dec.verify_step(
            params, cfg, cache, tok, pos, nv, pt, model_axis=MODEL_AXIS
        )

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),
            cache_ps,
            P(DATA_AXIS, None),
            P(DATA_AXIS),
            P(DATA_AXIS),
            P(DATA_AXIS, None),
        ),
        out_specs=(P(DATA_AXIS, None, None), cache_ps),
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(1,))


def _window_pspecs(cfg):
    """PartitionSpec tree for `verify_tree_step`'s window K/V aux output:
    per layer {"k","v"} of (B, local kv heads, T, dh) — batch over
    `data`, kv heads over `model` (captured AFTER the head slice, so the
    leaves line up with the cache's head sharding)."""
    scanned = cfg.scan_layers and cfg.repeats > 1
    if scanned:
        leaf = P(None, DATA_AXIS, MODEL_AXIS, None, None)
        return {f"p{i}": {"k": leaf, "v": leaf}
                for i in range(len(cfg.layer_pattern))}
    leaf = P(DATA_AXIS, MODEL_AXIS, None, None)
    return {f"layer{i}": {"k": leaf, "v": leaf}
            for i in range(cfg.num_layers)}


def verify_tree_fn(cfg, mesh, cache_ps, depths, anc):
    """The sharded TREE verify: (params, cache, tok (B, T), pos, tables)
    -> (logits (B, T, V), window_kv).  The static topology (depths, anc)
    is closed over as constants; the cache is read, never written — the
    accepted path lands via `commit_fn` after host-side acceptance."""

    def body(params, cache, tok, pos, pt):
        return Dec.verify_tree_step(
            params, cfg, cache, tok, pos, pt, depths, anc,
            model_axis=MODEL_AXIS
        )

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),
            cache_ps,
            P(DATA_AXIS, None),
            P(DATA_AXIS),
            P(DATA_AXIS, None),
        ),
        out_specs=(P(DATA_AXIS, None, None), _window_pspecs(cfg)),
        check_rep=False,
    )
    return jax.jit(fn)


def commit_fn(cfg, mesh, cache_ps):
    """The sharded accepted-path commit: (cache, window_kv, tables, pos,
    path, cnt) -> cache.  Pure per-shard scatters (local pages x local
    kv heads) — no collectives, bit-identical to the replicated commit."""

    def body(cache, w, pt, pos, path, cnt):
        return Dec.commit_window(cfg, cache, w, pt, pos, path, cnt)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            cache_ps,
            _window_pspecs(cfg),
            P(DATA_AXIS, None),
            P(DATA_AXIS),
            P(DATA_AXIS, None),
            P(DATA_AXIS),
        ),
        out_specs=cache_ps,
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(0,))


def chunk_fn(cfg, mesh, cache_ps, start: int, bucket_len: int):
    """One sharded prefill chunk: (params, cache, toks, tables,
    write_tables, last_index) -> (logits (D, V), cache).  Row d of every
    operand belongs to data shard d; only the owner's row is live."""

    def body(params, cache, toks, pt, wt, li):
        return Dec.prefill_chunk(
            params,
            cfg,
            cache,
            toks,
            pt,
            start=start,
            last_index=li,
            bucket_len=bucket_len,
            write_tables=wt,
            model_axis=MODEL_AXIS,
        )

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),
            cache_ps,
            P(DATA_AXIS, None),
            P(DATA_AXIS, None),
            P(DATA_AXIS, None),
            P(DATA_AXIS),
        ),
        out_specs=(P(DATA_AXIS, None), cache_ps),
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(1,))
