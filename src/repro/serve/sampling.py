"""Token sampling inside the jitted decode loop.

`SamplingSpec` is the per-request policy (greedy / temperature / top-k /
top-p, with a per-request seed).  `sample_tokens` is the jit-safe batched
kernel: every slot carries its *own* temperature/top-k/top-p/key, so one
decode step can serve heterogeneous sampling policies.

Masking is sort-based (rank + cumulative probability) rather than
`lax.top_k`, because k and p are *traced per-slot values* — the same
compiled executable serves every policy.  The Gumbel noise for a slot is a
function of that slot's key alone, which makes a request's token stream
independent of its co-residents and of its slot index (the
bit-identical-under-batching property tests/test_serve.py checks).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """Per-request sampling policy.  temperature 0 = greedy (argmax)."""
    temperature: float = 0.0
    top_k: int = 0                 # 0 -> disabled (full vocab)
    top_p: float = 1.0             # 1 -> disabled
    seed: int = 0

    def __post_init__(self):
        assert self.temperature >= 0.0
        assert self.top_k >= 0
        assert 0.0 < self.top_p <= 1.0


def spec_arrays(specs) -> dict:
    """Stack per-request SamplingSpecs into the (B,) device arrays
    `sample_tokens` consumes.  `specs` is a list (one per slot/row)."""
    return {
        "temperature": jnp.asarray([s.temperature for s in specs], F32),
        "top_k": jnp.asarray([s.top_k for s in specs], jnp.int32),
        "top_p": jnp.asarray([s.top_p for s in specs], F32),
        "keys": jnp.stack([jax.random.PRNGKey(s.seed) for s in specs]),
    }


def _gumbel_rows(keys, shape_v):
    """Per-row Gumbel noise: row i depends only on keys[i]."""
    return jax.vmap(lambda k: jax.random.gumbel(k, (shape_v,), F32))(keys)


def sample_tokens(logits, keys, temperature, top_k, top_p):
    """logits (B, V) f32; keys (B, 2) PRNGKeys; temperature/top_k/top_p (B,).

    Returns (B,) int32 tokens.  Rows with temperature == 0 take the argmax;
    the rest sample from the top-k/top-p-truncated tempered distribution via
    the Gumbel-max trick (one argmax, no categorical resampling).
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1)

    temp = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = logits.astype(F32) / temp

    # rank of every vocab entry within its row, descending by logit
    order = jnp.argsort(-scaled, axis=-1)                  # (B, V)
    ranks = jnp.argsort(order, axis=-1)
    k = jnp.where(top_k <= 0, V, top_k)[:, None]
    keep_k = ranks < k

    # nucleus: keep tokens whose preceding cumulative mass is < top_p
    # (always keeps the top-1 token, matching the standard formulation)
    sorted_probs = jax.nn.softmax(
        jnp.take_along_axis(scaled, order, axis=-1), axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    keep_sorted = (cum - sorted_probs) < top_p[:, None]
    keep_p = jnp.take_along_axis(keep_sorted, ranks, axis=-1)

    masked = jnp.where(keep_k & keep_p, scaled, -jnp.inf)
    sampled = jnp.argmax(masked + _gumbel_rows(keys, V), axis=-1)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)


def truncated_probs(logits: np.ndarray, spec: SamplingSpec) -> np.ndarray:
    """The exact distribution `sample_tokens` draws from, as a host array.

    Mirrors the device kernel's truncation semantics — rank-based top-k,
    preceding-cumulative-mass top-p (the top-1 token always survives),
    temperature scaling in f32 — then renormalizes over the keep set.
    The speculative-decoding acceptance rule (serve/spec.py) is defined
    against THIS distribution, which is what makes residual rejection
    sampling lossless w.r.t. the vanilla sampler.  The nucleus boundary
    is accumulated in f32 to track the device arithmetic; a backend that
    lowers softmax/cumsum as a differently-associated reduction could in
    principle flip a token sitting exactly on the top-p boundary by one
    ulp — a measure-zero disagreement the statistical losslessness tests
    bound, not a structural one."""
    assert spec.temperature > 0.0, "truncated_probs is for sampling policies"
    v = logits.shape[-1]
    scaled = np.asarray(logits, np.float32) / np.float32(
        max(spec.temperature, 1e-6))
    order = np.argsort(-scaled, kind="stable")
    ranks = np.argsort(order, kind="stable")
    k = v if spec.top_k <= 0 else spec.top_k
    keep = ranks < k
    # the keep SET must match the device bit-for-bit, so the nucleus
    # boundary is computed in float32 exactly as sample_tokens does
    # (softmax + cumsum in f32); only the final renormalization over the
    # agreed keep set is done in f64 for sampling stability
    sorted_scaled = scaled[order]
    ex = np.exp(sorted_scaled - sorted_scaled[0], dtype=np.float32)
    sorted_probs = (ex / ex.sum(dtype=np.float32)).astype(np.float32)
    cum = np.cumsum(sorted_probs, dtype=np.float32)
    keep &= ((cum - sorted_probs) < np.float32(spec.top_p))[ranks]
    p = np.where(keep, np.exp((scaled - scaled.max()).astype(np.float64)), 0.0)
    return p / p.sum()


def fold_step_keys(keys, step):
    """Advance every slot's key stream to `step` (B-vmapped fold_in)."""
    return jax.vmap(lambda k: jax.random.fold_in(k, step))(keys)


def uniform_spec_arrays(spec: SamplingSpec, batch: int) -> dict:
    """One spec replicated across a batch, with per-row derived seeds."""
    base = jax.random.PRNGKey(spec.seed)
    return {
        "temperature": jnp.full((batch,), spec.temperature, F32),
        "top_k": jnp.full((batch,), spec.top_k, jnp.int32),
        "top_p": jnp.full((batch,), spec.top_p, F32),
        "keys": jax.vmap(lambda i: jax.random.fold_in(base, i))(
            jnp.arange(batch)),
    }
