"""The generation Engine: compiled prefill/decode executables + a fully
jitted token loop + block-paged continuous batching.

Two serving modes over one set of compiled artifacts:

  * `generate(prompts, ...)` — batch-synchronous: ONE jitted call runs
    prefill and the whole stop-token-aware decode loop under
    `jax.lax.while_loop` (no per-token Python dispatch);
  * `submit() / step() / drain()` — continuous batching over a `PagePool`:
    requests own refcounted page lists instead of contiguous slot rows,
    prompts are prefilled `prefill_chunk` blocks at a time INTERLEAVED
    with decode steps (admitting a long prompt no longer stalls
    co-residents' token cadence), and common global-prefix pages are
    admitted once and shared (DESIGN.md §Paged cache).

Executables are cached by bucketed shapes: prompts are right-padded to a
power-of-two bucket (exact under causal attention because logits are
gathered at the per-row `last_index`, see models/decode.prefill), decode
loops are compiled per power-of-two `max_new` bucket with the true limit
passed as a traced operand (one executable serves every `max_new` in the
bucket), and prefill chunks are compiled per chunk offset.  Configs with
recurrent layers (mamba/rwkv state) prefill at the exact prompt length in
one shot — right-padding or chunk-splitting would corrupt their running
state.

`mesh=` (a (data, model) mesh) makes the continuous-batching path
mesh-parallel: slots and the paged KV pool partition over `data`, kv
heads over `model`, and the decode/prefill-chunk executables run under
`shard_map` with token streams bit-identical to the replicated engine
(DESIGN.md §Mesh-parallel serving).

`spec=` (a `SpecConfig`) turns on speculative decoding over the
continuous-batching path: a draft provider proposes up to k tokens per
slot per step, ONE multi-token verify forward scores them all, and a
lossless acceptance rule (greedy exact-match / residual rejection
sampling) emits 1..k+1 tokens per round (serve/spec.py, DESIGN.md
§Speculative decoding).
"""
from __future__ import annotations

import collections
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.obs import metrics as Om
from repro.obs import trace as Tr
from repro.obs.clock import clock
from repro.obs.trace import TRACE
from repro.models import decode as Dec
from repro.models import model as M
from repro.serve import sampling as Smp
from repro.serve import spec as Spc
from repro.serve.api import GenerateOutput, PoolStats, Request, Result
from repro.serve.batching import PagePool, SlotState, pow2_bucket
from repro.serve.sampling import SamplingSpec
from repro.serve.spec import SpecConfig

I32 = jnp.int32


def _has_recurrent_layers(cfg: M.ModelConfig) -> bool:
    return any(ls.kind in ("mamba", "rwkv") for ls in cfg.layer_pattern)


def _attn_only(cfg: M.ModelConfig) -> bool:
    return all(ls.kind == "attn" for ls in cfg.layer_pattern)


class Engine:
    """Owns params + compiled serving executables for one ModelConfig."""

    def __init__(self, cfg: M.ModelConfig, params, *, max_len: int = 0,
                 capacity: int = 4, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = 4, mesh=None,
                 spec: Optional[SpecConfig] = None,
                 ragged_prefill: Optional[bool] = None,
                 dispatch_depth: int = 1, kv_dtype=None,
                 host_swap: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len or (cfg.dec_len if cfg.kind == "encdec"
                                   else cfg.max_seq)
        self.capacity = capacity
        self._exact_prefill = _has_recurrent_layers(cfg)
        # chunked prefill needs attention-only causal stacks; everything
        # else admits one-shot (recurrent state must stream sequentially)
        self._chunked = (prefill_chunk is not None and _attn_only(cfg)
                         and cfg.kind == "lm"
                         and all(cfg.attn_spec(ls).causal
                                 for ls in cfg.layer_pattern))

        # (data, model) serving mesh: slots/pages shard over data, kv heads
        # over model (DESIGN.md §Mesh-parallel serving).  The sharded path
        # admits exclusively through chunked prefill.
        self.mesh = mesh
        data_shards = 1
        if mesh is not None:
            from repro.dist import sharding as Sh
            data_shards, _ = Sh.validate_serving_mesh(cfg, mesh, capacity,
                                                      num_pages)
            if not self._chunked:
                raise ValueError(
                    "mesh serving requires the chunked-prefill path: an "
                    "attention-only causal LM config with prefill_chunk set")
        self._data_shards = data_shards

        # compiled executables; jax.jit keys its cache by the (bucketed)
        # input shapes, so each bucket compiles exactly once per engine
        self._admit_prefill = jax.jit(
            lambda p, b, li, ml: Dec.prefill(p, cfg, b, ml, last_index=li),
            static_argnums=(3,))
        self._slot_step = jax.jit(self._slot_step_impl, donate_argnums=(1,))
        self._generate = {}            # bucketed max_new -> jitted loop
        self._chunk_fns = {}           # (start, bucket_len) -> jitted chunk
        self._ragged_fns = {}          # graph_key -> jitted ragged chunk
        self._gk_bucket = {}           # graph_key -> canonical bucket_len

        # ragged multi-prompt prefill: chunks of several co-admitted
        # prompts batch into one forward (default on for unsharded chunked
        # engines; the mesh path keeps per-slot static chunks — both are
        # bit-identical to one-shot prefill, so mixing them is safe)
        self._ragged = (self._chunked and mesh is None
                        if ragged_prefill is None
                        else ragged_prefill and self._chunked
                        and mesh is None)

        # decode dispatch pipelining: keep up to `dispatch_depth` decode
        # steps in flight before materializing results on the host (the
        # async front-end's latency hiding; 1 = fully synchronous)
        self._depth = max(1, int(dispatch_depth))
        self._inflight: collections.deque = collections.deque()
        self._pending_finished: List[Result] = []

        # quantized KV pages (kv_dtype=int8: ~4x smaller pages, lossy —
        # NLL-delta-gated) + host-memory swap tier (exact: swapped streams
        # are digest-identical).  Default (None/False) is the parity path.
        self.kv_dtype = None if kv_dtype is None else jnp.dtype(kv_dtype)
        self._host_swap = bool(host_swap)
        if self._host_swap and mesh is not None:
            raise ValueError("host_swap requires an unsharded engine "
                             "(mesh=None): the swap tier moves whole pages "
                             "through the host, not shard-local slices")
        if self._host_swap and cfg.kind != "lm":
            raise ValueError("host_swap requires the paged slot path "
                             "(decoder-only LM configs)")

        # continuous-batching state (decoder-only LMs; encdec/patch archs
        # serve through generate() and never touch the pool)
        self.pool = (PagePool(cfg, capacity, self.max_len, num_pages,
                              data_shards=data_shards,
                              kv_dtype=self.kv_dtype)
                     if cfg.kind == "lm" else None)
        self._score_pool = None        # lazy B=1 pool for Engine.score
        self._score_fn = None
        if mesh is not None:
            from repro.serve import mesh as Mx
            self._cache_ps = Mx.cache_pspecs(cfg, capacity, self.max_len,
                                             self.pool.num_pages,
                                             kv_dtype=self.kv_dtype)
            self.pool.cache = Mx.place_cache(self.pool.cache, mesh,
                                             self._cache_ps)
            self.params = Mx.replicate(params, mesh)
            self._slot_step = Mx.slot_step_fn(cfg, mesh, self._cache_ps)
        self._chunk_tokens = (prefill_chunk * self.pool.page_size
                              if self._chunked else None)

        # speculative decoding: draft provider + the multi-token verify
        # executable (serve/spec.py; DESIGN.md §Speculative decoding)
        self.spec = spec
        self._provider = None
        self._accept_hist = None
        if spec is not None:
            if (self.pool is None or not _attn_only(cfg)
                    or not all(cfg.attn_spec(ls).causal
                               for ls in cfg.layer_pattern)):
                raise ValueError(
                    "speculative decoding requires an attention-only "
                    "causal LM config (the paged verify envelope)")
            self._provider = Spc.make_provider(spec, cfg, capacity,
                                               self.max_len)
            if spec.provider == "tree":
                # static tree topology, closed over the verify/commit
                # executables as numpy constants (no traced operands)
                self._topo = Spc.tree_topology(spec.fanout)
                self._accept_hist = np.zeros(self._topo.depth + 1, np.int64)
                self._offspine_hist = np.zeros(self._topo.depth + 1,
                                               np.int64)
                self._draft_spec = SamplingSpec(
                    temperature=spec.draft_temperature,
                    top_k=spec.draft_top_k, top_p=spec.draft_top_p, seed=0)
                depths_c, anc_c = self._topo.depths, self._topo.anc
                if mesh is not None:
                    from repro.serve import mesh as Mx
                    self._verify_tree = Mx.verify_tree_fn(
                        cfg, mesh, self._cache_ps, depths_c, anc_c)
                    self._commit_tree = Mx.commit_fn(cfg, mesh,
                                                     self._cache_ps)
                else:
                    self._verify_tree = jax.jit(
                        lambda p, c, tok, pos, pt: Dec.verify_tree_step(
                            p, cfg, c, tok, pos, pt, depths_c, anc_c))
                    self._commit_tree = jax.jit(
                        lambda c, w, pt, pos, path, cnt: Dec.commit_window(
                            cfg, c, w, pt, pos, path, cnt),
                        donate_argnums=(0,))
            else:
                self._accept_hist = np.zeros(spec.k + 1, np.int64)
                if mesh is not None:
                    from repro.serve import mesh as Mx
                    self._verify = Mx.verify_fn(cfg, mesh, self._cache_ps)
                else:
                    self._verify = jax.jit(
                        lambda p, c, tok, pos, nv, pt: Dec.verify_step(
                            p, cfg, c, tok, pos, nv, pt),
                        donate_argnums=(1,))
        self._queue: collections.deque = collections.deque()
        self._slot_meta: dict = {}     # slot -> (request, base key, submit step)
        self._next_id = 0
        self._step_count = 0

        # observability handles (repro/obs): get-or-create on the process-
        # global registry.  Every record below is a host-side dict update
        # strictly outside jitted regions — no device syncs ride on a
        # metric — and obs.metrics.disable() turns them all into no-ops
        # (the perf gate's metrics-on/off overhead contract).
        self._m_ttft = Om.histogram(
            "serve_ttft_seconds", "submit -> first token (s)")
        self._m_tpot = Om.histogram(
            "serve_tpot_seconds", "per output token after the first (s)")
        self._m_queue_wait = Om.histogram(
            "serve_queue_wait_seconds", "submit -> slot admission (s)")
        self._m_step = Om.histogram(
            "serve_step_seconds", "engine step wall-clock (s)")
        self._m_submitted = Om.counter(
            "serve_requests_submitted_total", "requests entering the queue")
        self._m_finished = Om.counter(
            "serve_requests_finished_total", "finished, by finish_reason")
        self._m_tokens = Om.counter(
            "serve_tokens_generated_total", "tokens emitted by finished "
            "requests")
        self._m_aborts = Om.counter(
            "serve_aborts_total", "Engine.abort cancellations applied")
        self._m_swap_out = Om.counter(
            "serve_swap_out_total", "residents swapped to the host tier")
        self._m_swap_in = Om.counter(
            "serve_swap_in_total", "swapped residents resumed on device")
        self._m_spec_proposed = Om.counter(
            "serve_spec_proposed_tokens_total", "draft tokens verified")
        self._m_spec_accepted = Om.counter(
            "serve_spec_accepted_tokens_total", "draft tokens accepted")
        self._m_accept_len = Om.histogram(
            "serve_spec_accept_len", "accepted draft tokens per verify "
            "round", buckets=tuple(float(i) for i in range(33)))
        self._m_pages_in_use = Om.gauge(
            "serve_pages_in_use", "KV pages currently mapped")
        self._m_pages_reserved = Om.gauge(
            "serve_pages_reserved", "KV pages promised but unmapped")
        self._m_pages_host = Om.gauge(
            "serve_pages_host", "KV pages parked in the host swap tier")
        self._m_queue_depth = Om.gauge(
            "serve_queue_depth", "requests waiting in the engine queue")

    @property
    def dispatch_depth(self) -> int:
        """Decode steps kept in flight before host materialization (1 =
        fully synchronous).  Host-side scheduling only — executables and
        token streams are identical at every depth — so it may be changed
        between steps (the bench flips it without rebuilding the engine)."""
        return self._depth

    @dispatch_depth.setter
    def dispatch_depth(self, depth: int):
        assert not self._inflight, \
            "change dispatch_depth between steps (pipeline is in flight)"
        self._depth = max(1, int(depth))

    # ------------------------------------------------------------------
    # shape bucketing
    # ------------------------------------------------------------------

    def bucket_len(self, n: int) -> int:
        """Compiled prompt-length bucket for an n-token prompt."""
        assert 1 <= n <= self.max_len, (n, self.max_len)
        if self._exact_prefill:
            return n                   # recurrent state: no right-padding
        return pow2_bucket(n, self.max_len)

    def bucket_new(self, n: int) -> int:
        """Compiled decode-loop bucket for max_new: power of two, with the
        true limit passed as a traced operand (tail steps are skipped by
        the loop condition, not by a separate executable)."""
        return pow2_bucket(n, 1 << 62)

    def _page_bucket(self, n: int) -> int:
        """Prompt bucket rounded up to a whole number of pages — the
        length one-shot admit prefill runs at and the graph key chunked
        prefill mirrors (models/decode.prefill_chunk `bucket_len`)."""
        b = self.pool.page_size
        return -(-self.bucket_len(n) // b) * b

    def _graph_key(self, n: int):
        """Prefix-sharing key: the per-layer attention graph the prefill of
        an n-token prompt runs (BigBird pattern config, or the full-attn
        fallback when the pattern outgrows the prompt bucket).  Two prompts
        with equal keys and equal prefix tokens produce bit-identical
        prefix K/V pages, even from different prompt buckets — the bucket
        only enters the computation through this decision."""
        bl = self._page_bucket(n)
        nbk = bl // self.pool.page_size
        key = []
        for ls in self.cfg.layer_pattern:
            spec = self.cfg.attn_spec(ls)
            if spec.kind in ("bigbird", "window"):
                bb = spec.bigbird_config(bl)
                key.append(bb if patterns.fits(bb, nbk) else "full")
            else:
                key.append("full")
        return tuple(key)

    def _pad_prompts(self, prompts):
        """Right-pad to one bucket; returns (tokens (B,Sb), last_index (B,))."""
        arrs = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        lens = np.asarray([a.size for a in arrs], np.int32)
        if self._exact_prefill:
            assert len(set(lens.tolist())) == 1, \
                "recurrent-state configs need uniform prompt lengths per batch"
        sb = self.bucket_len(int(lens.max()))
        toks = np.zeros((len(arrs), sb), np.int32)
        for i, a in enumerate(arrs):
            toks[i, :a.size] = a
        return jnp.asarray(toks), jnp.asarray(lens - 1)

    # ------------------------------------------------------------------
    # batch-synchronous generation (fully jitted loop)
    # ------------------------------------------------------------------

    def _make_generate(self, bucket: int):
        cfg = self.cfg

        def gen(params, batch, last_index, samp, stop, limit):
            logits, cache = Dec.prefill(params, cfg, batch, self.max_len,
                                        last_index=last_index)
            B = logits.shape[0]
            tok0 = Smp.sample_tokens(
                logits, Smp.fold_step_keys(samp["keys"], 0),
                samp["temperature"], samp["top_k"], samp["top_p"])
            out = jnp.zeros((B, bucket), I32).at[:, 0].set(tok0)
            done = (stop >= 0) & (tok0 == stop)

            def cond(carry):
                i, _, _, _, done, _ = carry
                return (i < limit) & jnp.logical_not(done.all())

            def body(carry):
                i, tok, pos, cache, done, out = carry
                logits, cache = Dec.decode_step(params, cfg, cache,
                                                tok[:, None], pos)
                nxt = Smp.sample_tokens(
                    logits, Smp.fold_step_keys(samp["keys"], i),
                    samp["temperature"], samp["top_k"], samp["top_p"])
                nxt = jnp.where(done, 0, nxt)
                out = out.at[:, i].set(nxt)
                done = done | ((stop >= 0) & (nxt == stop))
                return (i + 1, nxt, pos + 1, cache, done, out)

            carry = (jnp.asarray(1, I32), tok0, last_index + 1, cache,
                     done, out)
            _, _, _, _, _, out = jax.lax.while_loop(cond, body, carry)
            return out

        return jax.jit(gen)

    def generate(self, prompts: Sequence, max_new: int,
                 sampling: SamplingSpec = SamplingSpec(),
                 stop_token: Optional[int] = None,
                 frames=None, frontend_embeds=None) -> GenerateOutput:
        """Generate `max_new` tokens for a batch of prompts in one jitted
        call: prefill emits token 0, then max_new - 1 in-loop decode steps
        (early exit when every row has hit `stop_token`)."""
        toks, last_index = self._pad_prompts(prompts)
        B, sb = toks.shape
        batch = {"tokens": toks}
        if frames is not None:
            batch["frames"] = frames
        if frontend_embeds is not None:
            batch["frontend_embeds"] = frontend_embeds
            # patch frontend: the first F positions of the embedded sequence
            # are the frontend embeds (models/model._embed_inputs), so the
            # real input ends no earlier than F-1 and the effective sequence
            # is at least F long — gather logits / start decode there
            F = frontend_embeds.shape[1]
            last_index = jnp.maximum(last_index, F - 1)
        assert int(jnp.max(last_index)) + max_new <= self.max_len, \
            "prompt + max_new exceeds engine max_len"
        bucket = self.bucket_new(max_new)
        if bucket not in self._generate:
            self._generate[bucket] = self._make_generate(bucket)
        samp = Smp.uniform_spec_arrays(sampling, B)
        stop = jnp.asarray(-1 if stop_token is None else stop_token, I32)
        out = np.asarray(self._generate[bucket](
            self.params, batch, last_index, samp, stop,
            jnp.asarray(max_new, I32)))[:, :max_new]
        lengths = np.full((B,), max_new, np.int32)
        if stop_token is not None:
            for i in range(B):
                hits = np.nonzero(out[i] == stop_token)[0]
                if hits.size:
                    lengths[i] = hits[0] + 1
        return GenerateOutput(tokens=out, lengths=lengths)

    # ------------------------------------------------------------------
    # continuous batching: submit / step / drain
    # ------------------------------------------------------------------

    def _slot_step_impl(self, params, cache, tok, pos, pt, samp, step_keys):
        logits, cache = Dec.decode_step(params, self.cfg, cache, tok, pos,
                                        page_tables=pt)
        nxt = Smp.sample_tokens(logits, step_keys, samp["temperature"],
                                samp["top_k"], samp["top_p"])
        return nxt, cache

    def _chunk_fn(self, start: int, bucket_len: int):
        key = (start, bucket_len)
        if key not in self._chunk_fns:
            cfg = self.cfg
            if self.mesh is not None:
                from repro.serve import mesh as Mx
                self._chunk_fns[key] = Mx.chunk_fn(
                    cfg, self.mesh, self._cache_ps, start, bucket_len)
            else:
                self._chunk_fns[key] = jax.jit(
                    lambda p, cache, toks, pt, wt, li: Dec.prefill_chunk(
                        p, cfg, cache, toks, pt, start=start, last_index=li,
                        bucket_len=bucket_len, write_tables=wt),
                    donate_argnums=(1,))
        return self._chunk_fns[key]

    def _ragged_fn(self, gk):
        """One jitted ragged-chunk executable per attention graph key: the
        chunk offsets are traced per-row operands, so every offset mix of
        every prompt bucket sharing the graph runs the same executable."""
        if gk not in self._ragged_fns:
            cfg = self.cfg
            bucket = self._gk_bucket[gk]
            self._ragged_fns[gk] = jax.jit(
                lambda p, cache, toks, pt, wt, li, st: Dec.prefill_ragged(
                    p, cfg, cache, toks, pt, starts=st, last_index=li,
                    bucket_len=bucket, write_tables=wt),
                donate_argnums=(1,))
        return self._ragged_fns[gk]

    def submit(self, request: Request,
               submit_time: Optional[float] = None) -> int:
        """Queue a request; it is admitted at the next step() boundary.
        `submit_time` (perf_counter seconds) backdates the latency clock —
        the async front-end passes its own arrival timestamp so queueing
        time it controls still counts into `Result.ttft_s`."""
        assert self.cfg.kind == "lm", \
            "slot batching serves decoder-only LMs; use generate() for encdec"
        assert self.cfg.frontend != "patch", \
            "slot batching is text-only; patch-frontend archs need " \
            "frontend_embeds — use generate()"
        assert request.prompt.size + request.max_new_tokens <= self.max_len + 1, \
            "prompt + max_new_tokens exceeds engine max_len"
        assert self.pool.pages_needed(
            int(request.prompt.size), request.max_new_tokens) \
            <= self.pool.pages_per_shard - 1, \
            "request needs more pages than one shard's sub-pool owns"
        if request.request_id is None:
            request.request_id = self._next_id
            self._next_id += 1
        now = clock() if submit_time is None else submit_time
        self._queue.append((request, self._step_count, now))
        self._m_submitted.inc()
        if TRACE.enabled:
            tid = request.request_id + 1
            TRACE.name_thread(tid, f"req {request.request_id}")
            TRACE.instant("submit", tid=tid, ts=now,
                          args={"prompt_len": int(request.prompt.size),
                                "max_new": request.max_new_tokens})
        return request.request_id

    def _first_token(self, state: SlotState):
        """Record the TTFT event for `state` (first sampled token): the
        timestamp feeding `Result.ttft_s`, the serve_ttft_seconds
        histogram, and the per-request trace timeline."""
        state.ttft_time = clock()
        self._m_ttft.observe(max(0.0, state.ttft_time - state.submit_time))
        if TRACE.enabled:
            TRACE.instant("first_token", tid=state.request_id + 1,
                          ts=state.ttft_time)

    def _sample_first(self, logits, sampling: SamplingSpec) -> int:
        samp1 = Smp.spec_arrays([sampling])
        return int(Smp.sample_tokens(
            logits, Smp.fold_step_keys(samp1["keys"], 0),
            samp1["temperature"], samp1["top_k"], samp1["top_p"])[0])

    def _admit_one(self, slot: int, request: Request, submit_step: int,
                   submit_time: float):
        prompt = request.prompt
        L = int(prompt.size)
        base_key = jax.random.PRNGKey(request.sampling.seed)
        graph_key = self._graph_key(L) if self._chunked else None
        state = SlotState(
            request_id=request.request_id, pos=L, generated=0,
            max_new=request.max_new_tokens, stop_token=request.stop_token,
            tokens=[], prompt_len=L, admit_step=self._step_count,
            phase="prefill" if self._chunked else "decode",
            submit_time=submit_time)
        self.pool.allocate(slot, prompt, request.max_new_tokens,
                           graph_key=graph_key, state=state)
        self._slot_meta[slot] = (request, base_key, submit_step)
        state.admit_time = clock()
        self._m_queue_wait.observe(max(0.0, state.admit_time - submit_time))
        if TRACE.enabled:
            tid = request.request_id + 1
            TRACE.span("queue_wait", submit_time, state.admit_time, tid=tid)
            TRACE.instant("admit", tid=tid, ts=state.admit_time,
                          args={"slot": slot, "pages": len(state.pages),
                                "shared_pages": state.shared_pages,
                                "reserved": state.reserved})
        if self._provider is not None:
            self._provider.admit(slot, prompt)
        if self._chunked:
            # prefix-shared pages cover whole chunks -> skip their compute;
            # the final chunk (holding position L-1) always runs
            C = self._chunk_tokens
            state.prefill_pos = (state.shared_pages
                                 * self.pool.page_size // C) * C
        else:
            toks, last_index = self._pad_prompts([prompt])
            logits, cache1 = self._admit_prefill(
                self.params, {"tokens": toks}, last_index,
                self._page_bucket(L))
            self.pool.write_prefill(slot, cache1)
            tok0 = self._sample_first(logits, request.sampling)
            state.tokens, state.generated = [tok0], 1
            self._first_token(state)
            if self._provider is not None:
                self._provider.observe(slot, [tok0])

    def _run_prefill_chunk(self, slot: int):
        """One chunk of one prefilling slot: forward [start, start+C) into
        its pages; on the final chunk, sample the first token (TTFT)."""
        s = self.pool.slots[slot]
        request, _, _ = self._slot_meta[slot]
        prompt, L = request.prompt, s.prompt_len
        start = s.prefill_pos
        # the final chunk is clamped so it never crosses the logical cache
        # end (the page table has no rows past max_pages); C is a function
        # of `start`, so the (start, bucket) executable key still holds
        S_log = self.pool.max_pages * self.pool.page_size
        C = min(self._chunk_tokens, S_log - start)
        toks = np.zeros((1, C), np.int32)
        real = prompt[start:start + C]
        toks[0, :real.size] = real
        # never write prefix-shared pages (refcount > 1): the write view of
        # the table redirects their blocks to the dump page, while reads
        # keep resolving to the real shared pages
        pt = self.pool.table_row(slot)
        wt = pt.copy()
        wt[0, :s.shared_pages] = 0
        li = np.asarray([L - 1], np.int32)
        shard = self.pool.slot_shard(slot)
        if self.mesh is not None:
            # SPMD: every data shard runs the same chunk tokens, but only
            # the owning shard's row maps live pages — the other rows read
            # and write their local dump page and their math is discarded
            D = self._data_shards
            toks = np.broadcast_to(toks, (D, C)).copy()
            pt_all = np.zeros((D, self.pool.max_pages), np.int32)
            wt_all = np.zeros((D, self.pool.max_pages), np.int32)
            pt_all[shard], wt_all[shard] = pt[0], wt[0]
            pt, wt = pt_all, wt_all
            li = np.full((D,), L - 1, np.int32)
        fn = self._chunk_fn(start, self._page_bucket(L))
        logits, self.pool.cache = fn(
            self.params, self.pool.cache, jnp.asarray(toks),
            jnp.asarray(pt), jnp.asarray(wt), jnp.asarray(li))
        if self.mesh is not None:
            logits = logits[shard:shard + 1]
        s.prefill_pos = start + C
        self.pool.register_prefix(slot, min(s.prefill_pos, L), prompt,
                                  self._graph_key(L))
        if TRACE.enabled:
            TRACE.instant("prefill_chunk", tid=s.request_id + 1,
                          args={"start": start, "tokens": int(C)})
        if s.prefill_pos >= L:                 # prompt done -> first token
            tok0 = self._sample_first(logits, request.sampling)
            s.tokens, s.generated = [tok0], 1
            s.phase = "decode"
            s.admit_step = self._step_count    # the TTFT event
            self._first_token(s)
            if self._provider is not None:
                self._provider.observe(slot, [tok0])

    def _prefill_groups(self, slots):
        """Partition prefilling slots into batched forwards.  Slots whose
        next chunk shares (graph key, offset) run one STATIC chunk
        executable at B = capacity; slots past the global query rows with a
        full-size in-bounds chunk join a RAGGED group per graph key — one
        executable serves every offset mix (models/decode.prefill_ragged).
        Chunks touching global query rows, full-attention-fallback graphs,
        or the clamped cache-end chunk stay static: their dense reduction
        shapes depend on the offset and cannot batch across rows."""
        psz = self.pool.page_size
        S_log = self.pool.max_pages * psz
        groups: dict = {}
        for slot in slots:
            s = self.pool.slots[slot]
            gk = self._graph_key(s.prompt_len)
            self._gk_bucket.setdefault(gk, self._page_bucket(s.prompt_len))
            start = s.prefill_pos
            ragged = False
            if self._ragged and all(not isinstance(e, str) for e in gk):
                gmax = max(e.num_global_blocks for e in gk)
                ragged = (start >= gmax * psz
                          and start + self._chunk_tokens <= S_log)
            key = ("ragged", gk) if ragged else ("static", gk, start)
            groups.setdefault(key, []).append(slot)
        return list(groups.items())

    def _run_prefill_group(self, key, slots) -> List[Result]:
        """One batched prefill forward over a group of co-prefilling slots.
        Rows without a member slot ride along idle: dump-page tables make
        their compute finite garbage that is never read back.  Per-row math
        is row-independent, so each member's chunk is bit-identical to
        running it alone (the chunked == one-shot contract holds)."""
        kind, gk = key[0], key[1]
        B, psz = self.capacity, self.pool.page_size
        if kind == "ragged":
            C = self._chunk_tokens
        else:
            start0 = key[2]
            S_log = self.pool.max_pages * psz
            C = min(self._chunk_tokens, S_log - start0)
        toks = np.zeros((B, C), np.int32)
        pt = np.zeros((B, self.pool.max_pages), np.int32)
        wt = np.zeros((B, self.pool.max_pages), np.int32)
        li = np.zeros((B,), np.int32)
        starts = np.zeros((B,), np.int32)
        for slot in slots:
            s = self.pool.slots[slot]
            request, _, _ = self._slot_meta[slot]
            st = s.prefill_pos
            real = request.prompt[st:st + C]
            toks[slot, :real.size] = real
            row = self.pool.table_row(slot)[0]
            pt[slot] = row
            wt[slot] = row
            wt[slot, :s.shared_pages] = 0  # never write prefix-shared pages
            li[slot] = s.prompt_len - 1
            starts[slot] = st
        if kind == "ragged":
            logits, self.pool.cache = self._ragged_fn(gk)(
                self.params, self.pool.cache, jnp.asarray(toks),
                jnp.asarray(pt), jnp.asarray(wt), jnp.asarray(li),
                jnp.asarray(starts))
        else:
            logits, self.pool.cache = self._chunk_fn(
                start0, self._gk_bucket[gk])(
                self.params, self.pool.cache, jnp.asarray(toks),
                jnp.asarray(pt), jnp.asarray(wt), jnp.asarray(li))
        finished: List[Result] = []
        for slot in slots:
            s = self.pool.slots[slot]
            request, _, _ = self._slot_meta[slot]
            s.prefill_pos += C
            self.pool.register_prefix(slot, min(s.prefill_pos, s.prompt_len),
                                      request.prompt, gk)
            if TRACE.enabled:
                TRACE.instant("prefill_chunk", tid=s.request_id + 1,
                              args={"start": int(s.prefill_pos - C),
                                    "tokens": int(C)})
            if s.prefill_pos >= s.prompt_len:  # prompt done -> first token
                tok0 = self._sample_first(logits[slot:slot + 1],
                                          request.sampling)
                s.tokens, s.generated = [tok0], 1
                s.phase = "decode"
                s.admit_step = self._step_count    # the TTFT event
                self._first_token(s)
                if self._provider is not None:
                    self._provider.observe(slot, [tok0])
                reason = self._slot_done(s)
                if reason:
                    finished.append(self._finish(slot, reason))
        return finished

    def _finish(self, slot: int, reason: str) -> Result:
        state = self.pool.slots[slot]
        _, _, submit_step = self._slot_meta.pop(slot)
        pages_used = len(state.pages)
        shared = state.shared_pages
        now = clock()
        n_out = len(state.tokens)
        self.pool.evict(slot)
        if self._provider is not None:
            self._provider.evict(slot)
        # a request can finish with tokens but no engine-observed first
        # token (aborted mid-prefill after a swap restored old tokens, or
        # backdated clocks in tests), so tpot_s guards on ttft_time being
        # set and clamps at 0.0 — never the now-minus-epoch garbage an
        # unset (falsy) timestamp would produce
        ttft_s = (max(0.0, state.ttft_time - state.submit_time)
                  if state.ttft_time else 0.0)
        tpot_s = (max(0.0, (now - state.ttft_time) / (n_out - 1))
                  if n_out > 1 and state.ttft_time else 0.0)
        queue_wait_s = (max(0.0, state.admit_time - state.submit_time)
                        if state.admit_time else 0.0)
        self._m_finished.inc(reason=reason)
        self._m_tokens.inc(n_out)
        if n_out > 1 and state.ttft_time:
            self._m_tpot.observe(tpot_s)
        if TRACE.enabled:
            t0 = state.submit_time if state.submit_time else now
            TRACE.span("request", t0, now, tid=state.request_id + 1,
                       args={"reason": reason, "tokens": n_out,
                             "pages_used": pages_used,
                             "shared_pages": shared,
                             "draft_accepted": state.draft_accepted,
                             "draft_proposed": state.draft_proposed})
        return Result(request_id=state.request_id, tokens=state.tokens,
                      prompt_len=state.prompt_len, finish_reason=reason,
                      ttft_steps=state.admit_step - submit_step + 1,
                      pages_used=pages_used, shared_prefix_pages=shared,
                      ttft_s=ttft_s, tpot_s=tpot_s,
                      queue_wait_s=queue_wait_s,
                      draft_proposed=state.draft_proposed,
                      draft_accepted=state.draft_accepted,
                      verify_steps=state.verify_steps)

    def _slot_done(self, state: SlotState) -> Optional[str]:
        if state.stop_token is not None and \
                state.tokens[-1] == state.stop_token:
            return "stop"
        if state.generated >= state.max_new:
            return "length"
        return None

    def stats(self) -> Optional[PoolStats]:
        """Page-pool snapshot; None for configs without a slot path
        (encdec / patch archs serve through generate() only)."""
        p = self.pool
        if p is None:
            return None
        return PoolStats(
            num_pages=p.num_pages - p.data_shards, page_size=p.page_size,
            pages_in_use=p.pages_in_use,
            peak_pages_in_use=p.peak_pages_in_use,
            prefix_hits=p.prefix_hits,
            prefix_pages_shared=p.prefix_pages_shared,
            requests_admitted=p.requests_admitted,
            kv_bytes_per_page=p.kv_bytes_per_page(),
            data_shards=p.data_shards,
            pages_per_shard=p.pages_per_shard - 1,
            pages_reserved=p.pages_reserved,
            pages_in_use_per_shard=[p.pages_in_use_shard(d)
                                    for d in range(p.data_shards)],
            peak_pages_per_shard=list(p.peak_pages_per_shard),
            kv_bytes_per_shard=p.pages_per_shard * p.kv_bytes_per_page(),
            pages_host=p.pages_host, swap_in=p.swap_in_count,
            swap_out=p.swap_out_count)

    def step(self) -> List[Result]:
        """One serving step: admit queued requests into free slots, run one
        prefill chunk per admitted-but-unfinished prompt, then one batched
        decode step over every decoding slot.  Returns newly finished
        requests."""
        finished: List[Result] = self._pending_finished
        self._pending_finished = []
        if self.pool is None:          # no slot path (encdec/patch archs)
            self._step_count += 1
            return finished
        t_step = clock()
        trace_on = TRACE.enabled

        # pipelined decode steps must drain before the decode membership
        # can change: admissions and prefill completions create new decode
        # slots whose first input token only exists on the host (swap-ins
        # rejoin the decode batch the same way)
        if self._inflight and (self._queue or self.pool.prefill_slots()
                               or self.pool.swapped_slots()):
            self._drain_inflight(finished)

        # resume swapped-out residents first, FIFO in swap-out order, from
        # pages freed since (they are older than anything still queued)
        if self._host_swap:
            self._resume_swapped()

        t_admit = clock() if trace_on else 0.0
        admitted = 0
        free = self.pool.free_slots()
        while free and self._queue:
            request, _, _ = self._queue[0]
            graph_key = (self._graph_key(int(request.prompt.size))
                         if self._chunked else None)
            # FIFO head-of-line per pool, but any data shard with a free
            # slot AND pages may take the head request (admission is
            # partitioned per shard; slot order is deterministic).
            # can_admit is shard-constant, so evaluate each shard once.
            slot, tried = None, set()
            for i in free:
                sh = self.pool.slot_shard(i)
                if sh in tried:
                    continue
                tried.add(sh)
                if self.pool.can_admit(request.prompt,
                                       request.max_new_tokens, graph_key,
                                       sh):
                    slot = i
                    break
            if slot is None:
                # page exhaustion with a free slot: the swap tier evicts
                # cold residents to host memory instead of hard-queueing
                if self._host_swap and self._swap_out_for_head(
                        request, graph_key, finished):
                    continue
                break                  # head-of-line: wait for pages
            free.remove(slot)
            request, submit_step, submit_time = self._queue.popleft()
            self._admit_one(slot, request, submit_step, submit_time)
            admitted += 1
            s = self.pool.slots[slot]
            if s.phase == "decode":
                reason = self._slot_done(s)
                if reason:             # stop/length hit on the prefill token
                    finished.append(self._finish(slot, reason))
        if trace_on and admitted:
            TRACE.span("admission", t_admit, args={"admitted": admitted})

        t_prefill = clock() if trace_on else 0.0
        prefilling = self.pool.prefill_slots()
        if prefilling and self.mesh is not None:
            # the mesh path keeps per-slot static chunks (SPMD row layout)
            for slot in prefilling:
                self._run_prefill_chunk(slot)
                s = self.pool.slots[slot]
                if s.phase == "decode":
                    reason = self._slot_done(s)
                    if reason:
                        finished.append(self._finish(slot, reason))
        elif prefilling:
            for key, group in self._prefill_groups(prefilling):
                finished.extend(self._run_prefill_group(key, group))
        if trace_on and prefilling:
            TRACE.span("prefill", t_prefill,
                       args={"slots": len(prefilling)})

        t_decode = clock() if trace_on else 0.0
        active = self.pool.decode_slots()
        if active and self.spec is not None:
            finished.extend(self._spec_decode(active))
            if trace_on:
                TRACE.span("spec_round", t_decode,
                           args={"slots": len(active)})
        elif active:
            if len(self._inflight) >= self._depth:
                self._collect_one(finished)
                active = self.pool.decode_slots()
            if active:
                ahead = len(self._inflight)
                # running ahead must not cross any slot's max_new budget:
                # the step after a length-finish would decode a dead slot
                run_ahead = ahead == 0 or all(
                    self.pool.slots[i].generated + ahead
                    < self.pool.slots[i].max_new for i in active)
                if not run_ahead:
                    self._drain_inflight(finished)
                    active = self.pool.decode_slots()
                if active:
                    self._dispatch_decode(active)
                    if self._depth <= 1:
                        self._collect_one(finished)
            if trace_on:
                TRACE.span("decode", t_decode,
                           args={"slots": len(active)})
        elif self._inflight:
            self._drain_inflight(finished)

        p = self.pool
        self._m_pages_in_use.set(p.pages_in_use)
        self._m_pages_reserved.set(p.pages_reserved)
        self._m_pages_host.set(p.pages_host)
        self._m_queue_depth.set(len(self._queue))
        self._m_step.observe(clock() - t_step)
        if trace_on:
            TRACE.span("engine_step", t_step,
                       args={"step": self._step_count})
        self._step_count += 1
        return finished

    # ------------------------------------------------------------------
    # pipelined decode dispatch (dispatch_depth > 1 keeps steps in flight)
    # ------------------------------------------------------------------

    def _dispatch_decode(self, active: List[int]):
        """Dispatch ONE batched decode step without materializing results.
        With `ahead` steps already in flight, slot positions/sample counts
        advance host-side by `ahead` and the input token is the previous
        step's device output — every device operand is identical to what a
        fully synchronous loop would feed, so pipelining is bit-identical
        (per-slot PRNG keys make sampled streams co-resident-independent)."""
        B, psz = self.capacity, self.pool.page_size
        ahead = len(self._inflight)
        tok_host = np.zeros((B, 1), np.int32)
        counts = np.zeros((B,), np.int32)
        pos = np.asarray(self.pool.position_vector())
        specs = [SamplingSpec()] * B
        keys = [jax.random.PRNGKey(0)] * B
        for i in active:
            s = self.pool.slots[i]
            self.pool.ensure_capacity(i, (s.pos + ahead) // psz)
            self.pool.ensure_writable(i, (s.pos + ahead) // psz)
            tok_host[i, 0] = s.tokens[-1]
            counts[i] = s.generated + ahead
            pos[i] = s.pos + ahead
            specs[i] = self._slot_meta[i][0].sampling
            keys[i] = self._slot_meta[i][1]
        samp = Smp.spec_arrays(specs)
        step_keys = jax.vmap(jax.random.fold_in)(
            jnp.stack(keys), jnp.asarray(counts))
        tok = (jnp.asarray(tok_host) if ahead == 0
               else self._inflight[-1]["nxt"][:, None])
        nxt, self.pool.cache = self._slot_step(
            self.params, self.pool.cache, tok, jnp.asarray(pos),
            jnp.asarray(self.pool.table_matrix()), samp, step_keys)
        self._inflight.append(
            {"nxt": nxt,
             "members": [(i, self.pool.slots[i].request_id)
                         for i in active]})

    def _collect_one(self, finished: List[Result]):
        """Materialize the OLDEST in-flight decode step on the host and
        apply its token to every member slot still holding that request.
        A finish changes the decode membership, so the rest of the pipeline
        drains too (later steps' tokens stay valid for survivors; the dead
        slot's rows are skipped by the request-id guard)."""
        entry = self._inflight.popleft()
        nxt = np.asarray(entry["nxt"])
        any_done = False
        for slot, rid in entry["members"]:
            s = self.pool.slots[slot]
            if s is None or s.request_id != rid:
                continue               # aborted while in flight
            s.tokens.append(int(nxt[slot]))
            s.generated += 1
            s.pos += 1
            reason = self._slot_done(s)
            if reason:
                finished.append(self._finish(slot, reason))
                any_done = True
        if any_done:
            self._drain_inflight(finished)

    def _drain_inflight(self, finished: List[Result]):
        while self._inflight:
            self._collect_one(finished)

    # ------------------------------------------------------------------
    # host-memory swap scheduling (mechanism: serve/batching.PagePool)
    # ------------------------------------------------------------------

    def _swap_graph_key(self, slot: int):
        request = self._slot_meta[slot][0]
        return (self._graph_key(int(request.prompt.size))
                if self._chunked else None)

    def _resume_swapped(self):
        """Swap resumable residents back in, strictly FIFO in swap-out
        order: the oldest swapped request resumes first or nobody does
        (skipping ahead could starve it forever).  `resume_gen` pins the
        progress gate — a resumed slot cannot be re-victimized until it
        has decoded at least one more token, so every swap cycle makes
        progress and the swap tier cannot livelock."""
        for slot in self.pool.swapped_slots():
            request = self._slot_meta[slot][0]
            gk = self._swap_graph_key(slot)
            if not self.pool.can_resume(slot, request.prompt, gk):
                break
            self.pool.swap_in(slot, request.prompt, gk)
            s = self.pool.slots[slot]
            s.resume_gen = s.generated
            self._m_swap_in.inc()
            if TRACE.enabled:
                TRACE.instant("swap_in", tid=s.request_id + 1,
                              args={"pages": len(s.pages)})

    def _swap_out_for_head(self, request, graph_key, finished) -> bool:
        """Make room for the head-of-line request by swapping decoding
        residents out to host memory.  Victims are picked by most
        remaining work (they would hold their pages longest), progress-
        gated on `resume_gen`; returns True once the head admits."""
        # in-flight decode steps reference the victims: drain first
        self._drain_inflight(finished)
        while not self.pool.can_admit(request.prompt,
                                      request.max_new_tokens, graph_key, 0):
            victims = [i for i in self.pool.decode_slots()
                       if self.pool.slots[i].generated
                       > self.pool.slots[i].resume_gen]
            if not victims:
                return False
            victim = max(victims, key=lambda i: (
                self.pool.slots[i].max_new - self.pool.slots[i].generated,
                -i))
            vs = self.pool.slots[victim]
            n_pages = len(vs.pages)
            self.pool.swap_out(victim)
            self._m_swap_out.inc()
            if TRACE.enabled:
                TRACE.instant("swap_out", tid=vs.request_id + 1,
                              args={"pages": n_pages})
        return True

    def swapped_requests(self) -> List[int]:
        """Request ids currently parked in the host swap tier (the async
        front-end's deadline sweep covers them: a swapped request can
        still expire and be aborted)."""
        if self.pool is None:
            return []
        return [self.pool.slots[i].request_id
                for i in self.pool.swapped_slots()]

    # ------------------------------------------------------------------
    # teacher-forced scoring (the bench's int8 NLL-delta probe)
    # ------------------------------------------------------------------

    def score(self, prompt, tokens) -> np.ndarray:
        """Per-token logprobs of `tokens` continuing `prompt`, teacher-
        forced through THIS engine's paged decode path (same kv_dtype, so
        an int8 engine scores through int8 pages).  Returns
        (len(tokens),) f32 with entry i = log p(tokens[i] | prompt,
        tokens[:i]).  Runs on a private B=1 pool; serving state is
        untouched."""
        assert self.pool is not None, "score() needs the paged slot path"
        assert self.mesh is None, "score() runs unsharded"
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        tokens = [int(t) for t in tokens]
        L, n = int(prompt.size), len(tokens)
        if n == 0:
            return np.zeros((0,), np.float32)
        assert L + n <= self.max_len + 1, (L, n, self.max_len)
        if self._score_pool is None:
            self._score_pool = PagePool(self.cfg, 1, self.max_len,
                                        kv_dtype=self.kv_dtype)
            self._score_fn = jax.jit(
                lambda p, c, tok, pos, pt: Dec.decode_step(
                    p, self.cfg, c, tok, pos, page_tables=pt),
                donate_argnums=(1,))
        pool = self._score_pool
        state = SlotState(request_id=-1, pos=L, generated=0, max_new=n,
                          stop_token=None, tokens=[], prompt_len=L,
                          admit_step=0)
        pool.allocate(0, prompt, n, state=state)
        try:
            toks, last_index = self._pad_prompts([prompt])
            logits, cache1 = self._admit_prefill(
                self.params, {"tokens": toks}, last_index,
                self._page_bucket(L))
            pool.write_prefill(0, cache1)
            lps = [float(jax.nn.log_softmax(
                logits[0].astype(jnp.float32))[tokens[0]])]
            for i in range(n - 1):
                pos = L + i
                pool.ensure_capacity(0, pos // pool.page_size)
                logits, pool.cache = self._score_fn(
                    self.params, pool.cache,
                    jnp.asarray([[tokens[i]]], I32),
                    jnp.asarray([pos], I32),
                    jnp.asarray(pool.table_matrix()))
                lps.append(float(jax.nn.log_softmax(
                    logits[0].astype(jnp.float32))[tokens[i + 1]]))
        finally:
            pool.evict(0)
        return np.asarray(lps, np.float32)

    # ------------------------------------------------------------------
    # cancellation
    # ------------------------------------------------------------------

    def abort(self, request_id: int) -> Optional[Result]:
        """Cancel a request wherever it is: still queued, mid-prefill, or
        mid-decode.  Frees the slot, unmaps/decrefs its pages (prefix pages
        shared CoW survive for their other sharers), and re-credits its
        page reservation; returns a Result with finish_reason="aborted"
        (tokens = whatever streamed so far), or None when the id is unknown
        (never submitted, or already finished)."""
        for idx, (request, _, _) in enumerate(self._queue):
            if request.request_id == request_id:
                del self._queue[idx]
                self._m_aborts.inc()
                self._m_finished.inc(reason="aborted")
                return Result(request_id=request_id, tokens=[],
                              prompt_len=int(request.prompt.size),
                              finish_reason="aborted")
        for slot, meta in list(self._slot_meta.items()):
            if meta[0].request_id != request_id:
                continue
            # in-flight decode steps reference the slot: drain them first
            # (co-residents' tokens surface at the next step(); the abortee
            # may legitimately finish while draining)
            self._drain_inflight(self._pending_finished)
            cur = self._slot_meta.get(slot)
            if cur is not None and cur[0].request_id == request_id:
                self._m_aborts.inc()
                return self._finish(slot, "aborted")
            for i, r in enumerate(self._pending_finished):
                if r.request_id == request_id:
                    return self._pending_finished.pop(i)
            return None
        return None

    # ------------------------------------------------------------------
    # speculative decoding: draft -> verify -> accept -> rollback
    # ------------------------------------------------------------------

    def _spec_decode(self, active: List[int]) -> List[Result]:
        """One draft/verify round over every decoding slot (replaces the
        single-token batched step when `spec=` is set).  Emits between 1
        and k+1 tokens per slot; the output stream is exactly the vanilla
        stream (greedy: token-identical; sampling: same distribution via
        residual rejection — serve/spec.py)."""
        if self.spec.provider == "tree":
            return self._spec_decode_tree(active)
        k = self.spec.k
        B, psz = self.capacity, self.pool.page_size
        pos = self.pool.position_vector()
        last = np.zeros((B,), np.int32)
        budgets = np.zeros((B,), np.int32)
        for i in active:
            s = self.pool.slots[i]
            last[i] = s.tokens[-1]
            # the window must stay inside the decode budget (the token
            # after the last accepted one is sampled, never written) and
            # inside the logical cache
            budgets[i] = max(0, min(k, s.max_new - s.generated - 1,
                                    self.max_len - 1 - s.pos))
        drafts, lens = self._provider.propose(active, last, budgets)
        tok = np.zeros((B, k + 1), np.int32)
        nval = np.zeros((B,), np.int32)
        for i in active:
            s = self.pool.slots[i]
            n = int(min(lens[i], budgets[i]))
            tok[i, 0] = last[i]
            tok[i, 1:1 + n] = drafts[i, :n]
            nval[i] = n
            # map + privatize every page the window [pos, pos+n] writes
            for blk in range(s.pos // psz, (s.pos + n) // psz + 1):
                self.pool.ensure_capacity(i, blk)
                self.pool.ensure_writable(i, blk)
        logits_dev, self.pool.cache = self._verify(
            self.params, self.pool.cache, jnp.asarray(tok),
            jnp.asarray(pos), jnp.asarray(nval),
            jnp.asarray(self.pool.table_matrix()))
        # all-greedy batches need only per-position argmaxes — (B, k+1)
        # int32 to host instead of the (B, k+1, V) f32 logits tensor
        all_greedy = all(
            self._slot_meta[i][0].sampling.temperature <= 0.0
            for i in active)
        if all_greedy:
            argmaxes = np.asarray(jnp.argmax(logits_dev, axis=-1))
            logits = None
        else:
            logits = np.asarray(logits_dev)            # (B, k+1, V) f32

        finished: List[Result] = []
        for i in active:
            s = self.pool.slots[i]
            n = int(nval[i])
            sampling = self._slot_meta[i][0].sampling
            if logits is None:
                emitted, m = Spc.accept_greedy(argmaxes[i, :n + 1],
                                               tok[i, 1:1 + n])
            else:
                rng = (Spc.accept_rng(sampling, s.generated)
                       if sampling.temperature > 0.0 else None)
                emitted, m = Spc.accept(logits[i, :n + 1], tok[i, 1:1 + n],
                                        sampling, rng)
            if s.stop_token is not None and s.stop_token in emitted:
                emitted = emitted[:emitted.index(s.stop_token) + 1]
            m = min(m, len(emitted))   # stop truncation caps what counts
            s.tokens.extend(emitted)
            s.generated += len(emitted)
            s.pos += len(emitted)
            s.draft_proposed += n
            s.draft_accepted += m
            s.verify_steps += 1
            self._accept_hist[m] += 1
            self._m_spec_proposed.inc(n)
            self._m_spec_accepted.inc(m)
            self._m_accept_len.observe(float(m))
            if TRACE.enabled:
                TRACE.instant("verify_round", tid=s.request_id + 1,
                              args={"proposed": n, "accepted": m})
            # paged rollback: unmap pages holding only rejected candidates
            self.pool.rollback(i, (s.pos - 1) // psz + 1)
            self._provider.observe(i, emitted)
            reason = self._slot_done(s)
            if reason:
                finished.append(self._finish(i, reason))
        return finished

    def _spec_decode_tree(self, active: List[int]) -> List[Result]:
        """One TREE draft/verify round (provider="tree").

        The draft proposes a static-topology token tree per slot
        (serve/spec.TreeDraft), `verify_tree_step` scores every node in
        ONE paged forward WITHOUT writing the cache (sibling nodes share
        logical positions), acceptance walks the tree per slot, and a
        single batched `commit_window` persists exactly the accepted
        root-to-leaf path before rollback unmaps everything past it —
        the pool never holds a rejected branch's K/V."""
        topo = self._topo
        D, T = topo.depth, topo.size
        B, psz = self.capacity, self.pool.page_size
        pos = self.pool.position_vector()
        budgets = np.zeros((B,), np.int32)
        for i in active:
            s = self.pool.slots[i]
            # accepted path depth is capped by the decode budget (the
            # token after the last accepted one is sampled, never
            # written) and by the logical cache end
            budgets[i] = max(0, min(D, s.max_new - s.generated - 1,
                                    self.max_len - 1 - s.pos))
        seeds = np.zeros((B,), np.uint32)
        for i in active:
            seeds[i] = np.uint32(
                self._slot_meta[i][0].sampling.seed & 0xFFFFFFFF)
        cand, draft_logits = self._provider.propose_tree(
            active, budgets, seeds)
        tok = np.zeros((B, T), np.int32)
        for i in active:
            s = self.pool.slots[i]
            tok[i, 0] = s.tokens[-1]
            tok[i, 1:] = cand[i]
            # map + privatize every page the accepted path could write
            # ([pos, pos + budget] — commit happens after acceptance)
            for blk in range(s.pos // psz,
                             (s.pos + int(budgets[i])) // psz + 1):
                self.pool.ensure_capacity(i, blk)
                self.pool.ensure_writable(i, blk)
        tables = jnp.asarray(self.pool.table_matrix())
        logits_dev, window_kv = self._verify_tree(
            self.params, self.pool.cache, jnp.asarray(tok),
            jnp.asarray(pos), tables)
        all_greedy = all(
            self._slot_meta[i][0].sampling.temperature <= 0.0
            for i in active)
        if all_greedy:
            argmaxes = np.asarray(jnp.argmax(logits_dev, axis=-1))
            logits = None
        else:
            logits = np.asarray(logits_dev)            # (B, T, V) f32

        path = np.zeros((B, D + 1), np.int32)
        cnt = np.zeros((B,), np.int32)
        emitted_by: dict = {}
        for i in active:
            s = self.pool.slots[i]
            sampling = self._slot_meta[i][0].sampling
            bud = int(budgets[i])
            if logits is None:
                emitted, m, fin = Spc.accept_tree_greedy(
                    argmaxes[i], tok[i], topo, bud)
            else:
                rng = (Spc.accept_rng(sampling, s.generated)
                       if sampling.temperature > 0.0 else None)
                dq = None
                if draft_logits is not None and sampling.temperature > 0.0:
                    dq = np.stack([
                        Smp.truncated_probs(draft_logits[i, d],
                                            self._draft_spec)
                        for d in range(D)])
                emitted, m, fin = Spc.accept_tree(
                    logits[i], tok[i], topo, bud, sampling, rng, dq)
            if s.stop_token is not None and s.stop_token in emitted:
                emitted = emitted[:emitted.index(s.stop_token) + 1]
            # sequential decode after emitting e_1..e_L holds K/V for the
            # root + e_1..e_{L-1} (the final token is the next pending
            # last): commit that many path entries, never more than the
            # accepted prefix the truncation kept
            m_kept = min(m, len(emitted))
            cnt[i] = min(m, len(emitted) - 1) + 1
            path[i, :m + 1] = topo.anc[fin, :m + 1]
            s.tokens.extend(emitted)
            s.generated += len(emitted)
            s.pos += len(emitted)
            s.draft_proposed += bud
            s.draft_accepted += m_kept
            s.verify_steps += 1
            self._accept_hist[m_kept] += 1
            self._m_spec_proposed.inc(bud)
            self._m_spec_accepted.inc(m_kept)
            self._m_accept_len.observe(float(m_kept))
            if TRACE.enabled:
                TRACE.instant("verify_round", tid=s.request_id + 1,
                              args={"proposed": bud, "accepted": m_kept})
            if int(topo.spine[m]) != fin:
                self._offspine_hist[m_kept] += 1
            emitted_by[i] = emitted

        # ONE batched commit of every slot's accepted path, against the
        # tables verify used (rollback below may unmap pages, so commit
        # strictly precedes it)
        self.pool.cache = self._commit_tree(
            self.pool.cache, window_kv, tables, jnp.asarray(pos),
            jnp.asarray(path), jnp.asarray(cnt))

        finished: List[Result] = []
        for i in active:
            s = self.pool.slots[i]
            self.pool.rollback(i, (s.pos - 1) // psz + 1)
            self._provider.observe(i, emitted_by[i])
            reason = self._slot_done(s)
            if reason:
                finished.append(self._finish(i, reason))
        return finished

    def spec_stats(self, reset: bool = False) -> Optional[dict]:
        """Aggregate speculative-decoding counters: the accepted-length
        histogram (index m = verify rounds that accepted m draft tokens)
        and the overall acceptance rate.  None when spec is off.  Tree
        providers add per-depth detail: `accept_len_hist[m]` is already
        "rounds whose accepted path reached depth m", and
        `offspine_hist[m]` counts those that ended on an OFF-spine
        candidate (branches paying their way)."""
        if self.spec is None:
            return None
        hist = self._accept_hist.copy()
        rounds = int(hist.sum())
        accepted = sum(m * int(c) for m, c in enumerate(hist))
        out = {
            "k": self.spec.k,
            "provider": self.spec.provider,
            "verify_rounds": rounds,
            "accept_len_hist": [int(c) for c in hist],
            "accepted_total": accepted,
            "mean_accepted_len": accepted / rounds if rounds else 0.0,
        }
        if self.spec.provider == "tree":
            out["fanout"] = list(self.spec.fanout)
            out["tree_nodes"] = self._topo.size
            out["offspine_hist"] = [int(c) for c in self._offspine_hist]
            out["offspine_accepted"] = int(self._offspine_hist.sum())
        if reset:
            self._accept_hist[:] = 0
            if self.spec.provider == "tree":
                self._offspine_hist[:] = 0
        return out

    def dump_trace(self, path: str) -> int:
        """Export the recorded event trace (obs.trace ring) to `path` as
        Chrome trace-event JSON; returns the number of events written.
        Recording must have been enabled (`obs.trace.enable()` or
        `launch/serve.py --trace`) for the ring to hold anything."""
        return Tr.dump(path)

    def drain(self) -> List[Result]:
        """Run step() until the queue and every slot are empty."""
        results: List[Result] = []
        while self._queue or self._inflight or self._pending_finished or (
                self.pool is not None and self.pool.active_slots()):
            results.extend(self.step())
        return sorted(results, key=lambda r: r.request_id)
