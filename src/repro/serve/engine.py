"""The generation Engine: compiled prefill/decode executables + a fully
jitted token loop + slot-based continuous batching.

Two serving modes over one set of compiled artifacts:

  * `generate(prompts, ...)` — batch-synchronous: ONE jitted call runs
    prefill and the whole stop-token-aware decode loop under
    `jax.lax.while_loop` (no per-token Python dispatch);
  * `submit() / step() / drain()` — continuous batching: requests are
    admitted into a fixed-capacity `SlotPool` at step boundaries, one
    jitted decode step serves all slots at their own positions, and
    finished slots free up for the next admit without any reshape/re-jit.

Executables are cached by bucketed shapes: prompts are right-padded to a
power-of-two bucket (exact under causal attention because logits are
gathered at the per-row `last_index`, see models/decode.prefill), so a
handful of compilations serve every prompt length.  Configs with
recurrent layers (mamba/rwkv state caches) prefill at the exact prompt
length instead — right-padding would pollute their running state.
"""
from __future__ import annotations

import collections
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as Dec
from repro.models import model as M
from repro.serve import sampling as Smp
from repro.serve.api import GenerateOutput, Request, Result
from repro.serve.batching import SlotPool, SlotState
from repro.serve.sampling import SamplingSpec

I32 = jnp.int32


def _has_recurrent_layers(cfg: M.ModelConfig) -> bool:
    return any(ls.kind in ("mamba", "rwkv") for ls in cfg.layer_pattern)


class Engine:
    """Owns params + compiled serving executables for one ModelConfig."""

    def __init__(self, cfg: M.ModelConfig, params, *, max_len: int = 0,
                 capacity: int = 4):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len or (cfg.dec_len if cfg.kind == "encdec"
                                   else cfg.max_seq)
        self.capacity = capacity
        self._exact_prefill = _has_recurrent_layers(cfg)

        # compiled executables; jax.jit keys its cache by the (bucketed)
        # input shapes, so each bucket compiles exactly once per engine
        self._prefill = jax.jit(
            lambda p, b, li: Dec.prefill(p, cfg, b, self.max_len,
                                         last_index=li))
        self._slot_step = jax.jit(self._slot_step_impl, donate_argnums=(1,))
        self._generate = {}            # max_new -> jitted loop

        # continuous-batching state
        self.pool = SlotPool(cfg, capacity, self.max_len)
        self._queue: collections.deque = collections.deque()
        self._slot_meta: dict = {}     # slot -> (sampling spec, base key)
        self._next_id = 0
        self._step_count = 0

    # ------------------------------------------------------------------
    # shape bucketing
    # ------------------------------------------------------------------

    def bucket_len(self, n: int) -> int:
        """Compiled prompt-length bucket for an n-token prompt."""
        assert 1 <= n <= self.max_len, (n, self.max_len)
        if self._exact_prefill:
            return n                   # recurrent state: no right-padding
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _pad_prompts(self, prompts):
        """Right-pad to one bucket; returns (tokens (B,Sb), last_index (B,))."""
        arrs = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        lens = np.asarray([a.size for a in arrs], np.int32)
        if self._exact_prefill:
            assert len(set(lens.tolist())) == 1, \
                "recurrent-state configs need uniform prompt lengths per batch"
        sb = self.bucket_len(int(lens.max()))
        toks = np.zeros((len(arrs), sb), np.int32)
        for i, a in enumerate(arrs):
            toks[i, :a.size] = a
        return jnp.asarray(toks), jnp.asarray(lens - 1)

    # ------------------------------------------------------------------
    # batch-synchronous generation (fully jitted loop)
    # ------------------------------------------------------------------

    def _make_generate(self, max_new: int):
        cfg = self.cfg

        def gen(params, batch, last_index, samp, stop):
            logits, cache = Dec.prefill(params, cfg, batch, self.max_len,
                                        last_index=last_index)
            B = logits.shape[0]
            tok0 = Smp.sample_tokens(
                logits, Smp.fold_step_keys(samp["keys"], 0),
                samp["temperature"], samp["top_k"], samp["top_p"])
            out = jnp.zeros((B, max_new), I32).at[:, 0].set(tok0)
            done = (stop >= 0) & (tok0 == stop)

            def cond(carry):
                i, _, _, _, done, _ = carry
                return (i < max_new) & jnp.logical_not(done.all())

            def body(carry):
                i, tok, pos, cache, done, out = carry
                logits, cache = Dec.decode_step(params, cfg, cache,
                                                tok[:, None], pos)
                nxt = Smp.sample_tokens(
                    logits, Smp.fold_step_keys(samp["keys"], i),
                    samp["temperature"], samp["top_k"], samp["top_p"])
                nxt = jnp.where(done, 0, nxt)
                out = out.at[:, i].set(nxt)
                done = done | ((stop >= 0) & (nxt == stop))
                return (i + 1, nxt, pos + 1, cache, done, out)

            carry = (jnp.asarray(1, I32), tok0, last_index + 1, cache,
                     done, out)
            _, _, _, _, _, out = jax.lax.while_loop(cond, body, carry)
            return out

        return jax.jit(gen)

    def generate(self, prompts: Sequence, max_new: int,
                 sampling: SamplingSpec = SamplingSpec(),
                 stop_token: Optional[int] = None,
                 frames=None, frontend_embeds=None) -> GenerateOutput:
        """Generate `max_new` tokens for a batch of prompts in one jitted
        call: prefill emits token 0, then max_new - 1 in-loop decode steps
        (early exit when every row has hit `stop_token`)."""
        toks, last_index = self._pad_prompts(prompts)
        B, sb = toks.shape
        batch = {"tokens": toks}
        if frames is not None:
            batch["frames"] = frames
        if frontend_embeds is not None:
            batch["frontend_embeds"] = frontend_embeds
            # patch frontend: the first F positions of the embedded sequence
            # are the frontend embeds (models/model._embed_inputs), so the
            # real input ends no earlier than F-1 and the effective sequence
            # is at least F long — gather logits / start decode there
            F = frontend_embeds.shape[1]
            last_index = jnp.maximum(last_index, F - 1)
        assert int(jnp.max(last_index)) + max_new <= self.max_len, \
            "prompt + max_new exceeds engine max_len"
        if max_new not in self._generate:
            self._generate[max_new] = self._make_generate(max_new)
        samp = Smp.uniform_spec_arrays(sampling, B)
        stop = jnp.asarray(-1 if stop_token is None else stop_token, I32)
        out = np.asarray(self._generate[max_new](
            self.params, batch, last_index, samp, stop))
        lengths = np.full((B,), max_new, np.int32)
        if stop_token is not None:
            for i in range(B):
                hits = np.nonzero(out[i] == stop_token)[0]
                if hits.size:
                    lengths[i] = hits[0] + 1
        return GenerateOutput(tokens=out, lengths=lengths)

    # ------------------------------------------------------------------
    # continuous batching: submit / step / drain
    # ------------------------------------------------------------------

    def _slot_step_impl(self, params, cache, tok, pos, samp, step_keys):
        logits, cache = Dec.decode_step(params, self.cfg, cache, tok, pos)
        nxt = Smp.sample_tokens(logits, step_keys, samp["temperature"],
                                samp["top_k"], samp["top_p"])
        return nxt, cache

    def submit(self, request: Request) -> int:
        """Queue a request; it is admitted at the next step() boundary."""
        assert self.cfg.kind == "lm", \
            "slot batching serves decoder-only LMs; use generate() for encdec"
        assert self.cfg.frontend != "patch", \
            "slot batching is text-only; patch-frontend archs need " \
            "frontend_embeds — use generate()"
        assert request.prompt.size + request.max_new_tokens <= self.max_len + 1, \
            "prompt + max_new_tokens exceeds engine max_len"
        if request.request_id is None:
            request.request_id = self._next_id
            self._next_id += 1
        self._queue.append((request, self._step_count))
        return request.request_id

    def _admit_one(self, slot: int, request: Request, submit_step: int):
        prompt = request.prompt
        L = int(prompt.size)
        toks, last_index = self._pad_prompts([prompt])
        logits, cache1 = self._prefill(self.params, {"tokens": toks},
                                       last_index)
        base_key = jax.random.PRNGKey(request.sampling.seed)
        samp1 = Smp.spec_arrays([request.sampling])
        tok0 = int(Smp.sample_tokens(
            logits, Smp.fold_step_keys(samp1["keys"], 0),
            samp1["temperature"], samp1["top_k"], samp1["top_p"])[0])
        state = SlotState(
            request_id=request.request_id, pos=L, generated=1,
            max_new=request.max_new_tokens, stop_token=request.stop_token,
            tokens=[tok0], prompt_len=L,
            admit_step=self._step_count)
        self.pool.admit(slot, cache1, state)
        self._slot_meta[slot] = (request.sampling, base_key, submit_step)

    def _finish(self, slot: int, reason: str) -> Result:
        state = self.pool.slots[slot]
        _, _, submit_step = self._slot_meta.pop(slot)
        self.pool.evict(slot)
        return Result(request_id=state.request_id, tokens=state.tokens,
                      prompt_len=state.prompt_len, finish_reason=reason,
                      ttft_steps=state.admit_step - submit_step + 1)

    def _slot_done(self, state: SlotState) -> Optional[str]:
        if state.stop_token is not None and \
                state.tokens[-1] == state.stop_token:
            return "stop"
        if state.generated >= state.max_new:
            return "length"
        return None

    def step(self) -> List[Result]:
        """One serving step: admit queued requests into free slots, then one
        batched decode step over every active slot.  Returns newly finished
        requests."""
        finished: List[Result] = []

        for slot in self.pool.free_slots():
            if not self._queue:
                break
            request, submit_step = self._queue.popleft()
            self._admit_one(slot, request, submit_step)
            reason = self._slot_done(self.pool.slots[slot])
            if reason:                 # stop/length hit on the prefill token
                finished.append(self._finish(slot, reason))

        active = self.pool.active_slots()
        if active:
            B = self.capacity
            tok = np.zeros((B, 1), np.int32)
            counts = np.zeros((B,), np.int32)
            specs = [SamplingSpec()] * B
            keys = [jax.random.PRNGKey(0)] * B
            for i in active:
                s = self.pool.slots[i]
                tok[i, 0] = s.tokens[-1]
                counts[i] = s.generated
                specs[i], keys[i] = self._slot_meta[i][0], self._slot_meta[i][1]
            samp = Smp.spec_arrays(specs)
            step_keys = jax.vmap(jax.random.fold_in)(
                jnp.stack(keys), jnp.asarray(counts))
            nxt, self.pool.cache = self._slot_step(
                self.params, self.pool.cache, jnp.asarray(tok),
                jnp.asarray(self.pool.position_vector()), samp, step_keys)
            nxt = np.asarray(nxt)
            for i in active:
                s = self.pool.slots[i]
                s.tokens.append(int(nxt[i]))
                s.generated += 1
                s.pos += 1
                reason = self._slot_done(s)
                if reason:
                    finished.append(self._finish(i, reason))

        self._step_count += 1
        return finished

    def drain(self) -> List[Result]:
        """Run step() until the queue and every slot are empty."""
        results: List[Result] = []
        while self._queue or self.pool.active_slots():
            results.extend(self.step())
        return sorted(results, key=lambda r: r.request_id)
