"""Unified generation Engine API.

    from repro.serve import Engine, Request, SamplingSpec

    eng = Engine(cfg, params, max_len=2048, capacity=8)
    out = eng.generate(prompts, max_new=64,
                       sampling=SamplingSpec(temperature=0.8, top_p=0.9))

    eng.submit(Request(prompt, max_new_tokens=32))   # continuous batching
    results = eng.drain()

    front = AsyncEngine(eng)                         # async token streams
    session = await front.submit(prompt, max_new_tokens=32)
    async for tok in session: ...

See DESIGN.md §Serving Engine and §Async front-end for the full contract.
"""
from repro.serve.api import GenerateOutput, PoolStats, Request, Result
from repro.serve.engine import Engine
from repro.serve.frontend import AsyncEngine, StreamSession
from repro.serve.sampling import SamplingSpec
from repro.serve.spec import ModelDraft, NGramDraft, SpecConfig, TreeDraft

__all__ = ["Engine", "AsyncEngine", "StreamSession", "Request", "Result",
           "GenerateOutput", "PoolStats", "SamplingSpec", "SpecConfig",
           "NGramDraft", "ModelDraft", "TreeDraft"]
