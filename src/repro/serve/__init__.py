"""Unified generation Engine API.

    from repro.serve import Engine, Request, SamplingSpec

    eng = Engine(cfg, params, max_len=2048, capacity=8)
    out = eng.generate(prompts, max_new=64,
                       sampling=SamplingSpec(temperature=0.8, top_p=0.9))

    eng.submit(Request(prompt, max_new_tokens=32))   # continuous batching
    results = eng.drain()

See DESIGN.md §Serving Engine for the full contract.
"""
from repro.serve.api import GenerateOutput, PoolStats, Request, Result
from repro.serve.engine import Engine
from repro.serve.sampling import SamplingSpec
from repro.serve.spec import ModelDraft, NGramDraft, SpecConfig

__all__ = ["Engine", "Request", "Result", "GenerateOutput", "PoolStats",
           "SamplingSpec", "SpecConfig", "NGramDraft", "ModelDraft"]
