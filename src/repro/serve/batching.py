"""Block-paged continuous batching over the model-zoo cache families.

A `PagePool` owns ONE device cache tree whose attention K/V leaves are a
flat pool of physical pages `(num_pages, Hkv, page_size, dh)` — page size
equals the BigBird pattern block size, so one pattern block is one page
and the bounded-decode read is a two-level lookup (pattern block -> page
table -> page).  Requests own *page lists* instead of contiguous slot
rows: admission RESERVES exactly the pages a request's prompt + budget
needs (so admission ordering is a pure function of the budget), but only
MAPS the pages covering the prompt — decode maps reserved pages lazily as
its write position crosses block boundaries (`ensure_capacity`), and the
speculative-decoding verify path returns wholly-rejected pages to the
free list (`rollback`), re-crediting the reservation.  Eviction releases
mapped pages and forfeits the remaining reservation; memory — not a
`capacity x max_len` reservation — is the only concurrency limit the
pool enforces.

Local page 0 of every data shard's sub-pool is a reserved DUMP page:
idle/prefilling rows of the batched decode step write their garbage KV
through all-zero (local-id) page-table rows, so the garbage lands on a
page no live request ever maps (reads through a zero entry are masked by
position before they can contribute).  With one data shard that is global
page 0 — the original contract, unchanged.

Shared global-prefix pages: the first `g` (global-block) pages of a
prompt are content-addressed — keyed by the exact token prefix they
cover plus the prefill graph — and REFCOUNTED, so co-resident requests
with a common prompt prefix map the same physical pages and the pages
are admitted (computed + written) once.  Copy-on-write protects sharers:
a write targeting a page with refcount > 1 first moves the writer onto a
private copy (`ensure_writable`).  Under the admission policy writes
never actually land on shared pages — decode writes at pos >= prompt_len
while shared pages cover full pages strictly below it — so the CoW path
is a guard, not a hot path (DESIGN.md §Paged cache).

Recurrent-state leaves (mamba `h/conv`, rwkv `tm/s/cm`) are O(1) per
request and keep the per-slot `(capacity, ...)` layout inside the same
tree.  Cache layout note: scanned configs (`cfg.scan_layers`, repeats >
1) prepend a repeats dim to every leaf; writers handle both.

Quantized pages (`kv_dtype=int8`): the stores carry int8 pages plus f32
scale leaves `ks`/`vs` (models/decode.cache_spec) — the pool's writers
quantize whole pages on the prefill scatter and copy/swap the scale rows
together with their pages.  Every page's int8 bytes are a pure function
of the graph and the tokens written since mapping, so content-addressed
prefix sharing is exactly as sound as in the f32 layout.

Host-memory swap tier (`host_swap=True`): `swap_out(slot)` copies ALL of
a resident slot's mapped pages (k/v and scale rows) to a host buffer,
releases the device pages like `evict` (shared prefix pages merely
decref — they leave the device only when every sharer is gone), and
parks the slot in phase "swapped" — it keeps its slot index but drops
out of the decode batch (dump-page table row, pinned position).
`swap_in` reattaches still-resident shared prefix pages by
content-address (bitwise identical by construction), scatters the host
copies back into freshly allocated pages for the rest, and restores the
reservation — a device->host->device roundtrip of exact bytes, so a
swapped-and-resumed request's stream is bitwise identical to a
never-swapped one.  Scheduling (who swaps, who resumes, when) lives in
the Engine; the pool only provides the mechanism + counters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as Dec

DUMP_PAGE = 0      # local id of every shard's dump page


def pow2_bucket(n: int, cap: int, floor: int = 16) -> int:
    """The compiled-shape bucket for an n-long operand: the smallest
    power of two >= n (>= floor), clamped to `cap`.  One policy shared by
    the Engine's prompt/max_new bucketing and the draft model's prefill
    (serve/spec.ModelDraft) — the executable-cache keying must not
    silently diverge between them."""
    b = floor
    while b < n:
        b *= 2
    return min(b, cap)


@dataclasses.dataclass
class SlotState:
    """Host-side bookkeeping for one occupied slot."""
    request_id: int
    pos: int                   # cache position the NEXT decode step writes
    generated: int             # tokens emitted so far
    max_new: int
    stop_token: Optional[int]
    tokens: list               # emitted tokens (host ints)
    prompt_len: int
    admit_step: int            # engine step counter at admission
    phase: str = "decode"      # "prefill" | "decode" | "swapped" (host tier)
    prefill_pos: int = 0       # next prompt position to prefill
    pages: list = dataclasses.field(default_factory=list)
    shared_pages: int = 0      # leading pages reused from the prefix index
    reserved: int = 0          # pages reserved but not yet mapped
    submit_time: float = 0.0   # wall-clock (obs.clock) at Engine.submit
    admit_time: float = 0.0    # wall-clock at slot admission (queue wait end)
    ttft_time: Optional[float] = None  # wall-clock at the first sampled
    #                            token; None until the engine observes one
    #                            (aborted/swapped finishes may never set it)
    draft_proposed: int = 0    # speculative draft tokens offered to verify
    draft_accepted: int = 0    # of which the target model accepted
    verify_steps: int = 0      # draft/verify rounds this request ran
    resume_gen: int = 0        # `generated` at last swap-in (progress gate)


class PagePool:
    """Refcounted page pool + per-slot page tables over one cache tree.

    With `data_shards` = D > 1 the pool is PARTITIONED along the mesh's
    data axis: slots are split into D contiguous rosters, pages into D
    sub-pools (each with its own dump page, free list, and refcounts), and
    a slot only ever maps pages of its own shard's sub-pool.  The physical
    stores keep ONE global leaf `(D * pages_per_shard, Hkv, b, dh)` whose
    page dim is device-sharded over `data`; host metadata uses GLOBAL page
    ids, and `table_matrix`/`table_row` emit shard-LOCAL ids — the
    coordinates the per-shard body of a `shard_map`'d step indexes with
    (DESIGN.md §Mesh-parallel serving).  D = 1 is exactly the old pool."""

    def __init__(self, cfg, capacity: int, max_len: int,
                 num_pages: Optional[int] = None, data_shards: int = 1,
                 kv_dtype=None):
        self.cfg, self.capacity, self.max_len = cfg, capacity, max_len
        self.kv_dtype = None if kv_dtype is None else jnp.dtype(kv_dtype)
        self.page_size = Dec.page_size_for(cfg)
        self.max_pages = -(-max_len // self.page_size)
        self._paged = any(ls.kind == "attn" for ls in cfg.layer_pattern)
        assert capacity % data_shards == 0, (capacity, data_shards)
        self.data_shards = data_shards
        self.cap_local = capacity // data_shards
        # default budget matches the old slot-contiguous reservation (so the
        # paged pool can always admit what the monolithic pool could) + one
        # dump page PER SHARD; callers shrink it to trade capacity for
        # memory.  An explicit num_pages is the total across shards.
        if num_pages is None:
            self.pages_per_shard = self.cap_local * self.max_pages + 1
        else:
            assert num_pages % data_shards == 0, (num_pages, data_shards)
            self.pages_per_shard = num_pages // data_shards
        self.num_pages = self.pages_per_shard * data_shards
        assert self.pages_per_shard >= 2, \
            "each shard needs its dump page + 1 real page"
        self.cache = Dec.cache_spec(cfg, capacity, max_len, abstract=False,
                                    num_pages=self.num_pages,
                                    kv_dtype=self.kv_dtype)
        self._scanned = cfg.scan_layers and cfg.repeats > 1
        self.page_tables = np.zeros((capacity, self.max_pages), np.int32)
        for slot in range(capacity):
            self.page_tables[slot, :] = self.dump_page(slot)
        self.slots: list = [None] * capacity       # SlotState | None
        # per-shard free lists of GLOBAL page ids (shard d owns the range
        # [d*pps, (d+1)*pps), its dump page d*pps excluded)
        pps = self.pages_per_shard
        self._free: list = [list(range(d * pps + 1, (d + 1) * pps))
                            for d in range(data_shards)]
        # pages promised to admitted requests but not yet mapped (lazy
        # mapping: decode maps them as its write position advances); they
        # stay in the free list but are invisible to admission and CoW
        self._reserved = [0] * data_shards
        self.refcount = np.zeros(self.num_pages, np.int64)
        # content-addressed prefix index: several co-resident requests may
        # hold equivalent (bit-identical) copies of the same prefix page —
        # all are indexed, so the key survives any one holder's eviction.
        # Sharing is intra-shard only (a table row cannot cross sub-pools).
        self._prefix: dict = {}      # (graph_key, token_bytes) -> {page ids}
        self._page_key: dict = {}    # page id -> its prefix-index key
        # the number of leading pages eligible for prefix sharing: the
        # pattern's global blocks (read by every query, forever)
        self._g_share = max(
            (cfg.attn_spec(ls).bigbird_config(
                max(self.max_pages, 1) * self.page_size).num_global_blocks
             for ls in cfg.layer_pattern
             if ls.kind == "attn"
             and cfg.attn_spec(ls).kind in ("bigbird", "window")),
            default=0)
        # host-memory swap tier: slot -> {"blob": host page copies (logical
        # page order), "n": page count, "reserved": stashed reservation}.
        # Dict insertion order is the swap-out order (FIFO resume policy).
        self._host: dict = {}
        self.swap_out_count = 0
        self.swap_in_count = 0
        self.pages_host_peak = 0
        # stats
        self.peak_pages_in_use = 0
        self.peak_pages_per_shard = [0] * data_shards
        self.prefix_hits = 0           # admits that reused >= 1 page
        self.prefix_pages_shared = 0   # cumulative pages NOT re-admitted
        self.requests_admitted = 0
        self._writer = jax.jit(self._write_impl, donate_argnums=(0,))
        self._copier = jax.jit(self._copy_impl, donate_argnums=(0,))
        self._page_reader = jax.jit(self._gather_pages_impl)
        self._page_scatter = jax.jit(self._scatter_pages_impl,
                                     donate_argnums=(0,))

    # -- shard geometry ----------------------------------------------------

    def slot_shard(self, slot: int) -> int:
        """Data shard owning `slot` (contiguous rosters of cap_local)."""
        return slot // self.cap_local

    def page_shard(self, page: int) -> int:
        """Data shard owning GLOBAL page id `page`."""
        return page // self.pages_per_shard

    def dump_page(self, slot: int) -> int:
        """GLOBAL id of the dump page of `slot`'s shard (local id 0)."""
        return self.slot_shard(slot) * self.pages_per_shard

    def _bump_peaks(self):
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)
        for d in range(self.data_shards):
            self.peak_pages_per_shard[d] = max(
                self.peak_pages_per_shard[d], self.pages_in_use_shard(d))

    # -- occupancy ---------------------------------------------------------

    def free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_slots(self):
        return [i for i, s in enumerate(self.slots) if s is not None]

    def decode_slots(self):
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.phase == "decode"]

    def prefill_slots(self):
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.phase == "prefill"]

    def swapped_slots(self):
        """Swapped-out resident slots, in swap-out (FIFO resume) order."""
        return [slot for slot in self._host
                if self.slots[slot] is not None
                and self.slots[slot].phase == "swapped"]

    @property
    def pages_in_use(self) -> int:
        free = sum(len(f) for f in self._free)
        return (self.num_pages - self.data_shards) - free

    def pages_in_use_shard(self, shard: int) -> int:
        return (self.pages_per_shard - 1) - len(self._free[shard])

    def pages_available(self, shard: int) -> int:
        """Free pages not spoken for by an admitted request's reservation
        — what admission and copy-on-write may actually take."""
        return len(self._free[shard]) - self._reserved[shard]

    @property
    def pages_reserved(self) -> int:
        """Pages promised to admitted requests but not yet mapped."""
        return sum(self._reserved)

    @property
    def pages_host(self) -> int:
        """Pages currently parked in the host-memory swap tier."""
        return sum(rec["n"] for rec in self._host.values())

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Logical pages a request occupies: prompt + decode writes (the
        last sampled token is never written).  Chunk-grid padding beyond
        this needs no pages — pad-region writes fall through the zeroed
        page-table tail onto the dump page."""
        b = self.page_size
        return min(-(-(prompt_len + max_new - 1) // b), self.max_pages)

    # -- prefix sharing ----------------------------------------------------

    def shareable_pages(self, prompt: np.ndarray) -> int:
        """Max leading pages of `prompt` eligible for sharing: full pages
        inside the global-block region, always leaving the page holding the
        last prompt token (which the final prefill chunk recomputes)."""
        L = int(prompt.size)
        return max(0, min(self._g_share, (L - 1) // self.page_size))

    def lookup_prefix(self, prompt: np.ndarray, graph_key,
                      shard: int = 0) -> list:
        """Longest chain of already-resident prefix pages for `prompt`,
        restricted to `shard`'s sub-pool (a table row never crosses it)."""
        pages = []
        b = self.page_size
        for j in range(1, self.shareable_pages(prompt) + 1):
            copies = self._prefix.get((graph_key, prompt[:j * b].tobytes()))
            local = [p for p in (copies or ())
                     if self.page_shard(p) == shard]
            if not local:
                break
            pages.append(min(local))           # deterministic pick
        return pages

    def register_prefix(self, slot: int, upto_pos: int, prompt: np.ndarray,
                        graph_key) -> None:
        """Publish the slot's written global-prefix pages (content now final
        — only pages fully covered by positions < upto_pos are eligible, so
        a later sharer never reads a page before its writer filled it)."""
        s = self.slots[slot]
        b = self.page_size
        hi = min(self.shareable_pages(prompt), upto_pos // b)
        for j in range(1, hi + 1):
            key = (graph_key, prompt[:j * b].tobytes())
            pg = s.pages[j - 1]
            if self._page_key.get(pg, key) != key:
                continue               # CoW moved this slot off a shared page
            self._prefix.setdefault(key, set()).add(pg)
            self._page_key[pg] = key

    # -- page allocation / release ----------------------------------------

    def can_admit(self, prompt: np.ndarray, max_new: int,
                  graph_key=None, shard: int = 0) -> bool:
        need = self.pages_needed(int(prompt.size), max_new)
        need -= len(self.lookup_prefix(prompt, graph_key, shard))
        return self.pages_available(shard) >= need

    def allocate(self, slot: int, prompt: np.ndarray, max_new: int,
                 graph_key=None,
                 state: Optional[SlotState] = None) -> SlotState:
        """Bind a page list + page-table row to `slot` for a new request.

        The full prompt+budget page count is RESERVED (admission ordering
        is unchanged by lazy mapping), but only the pages the prompt
        covers are mapped now; decode maps the rest on demand
        (`ensure_capacity`).  Leading pages come from the prefix index
        when the token prefix (and prefill graph) match — those are
        refcount-bumped, not rewritten.  Pages come exclusively from the
        slot's shard's sub-pool."""
        assert self.slots[slot] is None, f"slot {slot} occupied"
        assert state is not None
        assert state.pos + state.max_new <= self.max_len + 1, \
            f"request needs {state.pos + state.max_new} > max_len {self.max_len}"
        shard = self.slot_shard(slot)
        need = self.pages_needed(int(prompt.size), max_new)
        shared = self.lookup_prefix(prompt, graph_key, shard)
        map_n = min(-(-int(prompt.size) // self.page_size), need)
        assert len(shared) <= map_n
        fresh_n = map_n - len(shared)
        if self.pages_available(shard) < need - len(shared):
            raise RuntimeError(
                f"page pool exhausted: need {need - len(shared)}, "
                f"available {self.pages_available(shard)} (shard {shard})")
        fresh = [self._free[shard].pop() for _ in range(fresh_n)]
        pages = shared + fresh
        for pg in pages:
            self.refcount[pg] += 1
        state.pages = pages
        state.shared_pages = len(shared)
        state.reserved = need - map_n
        self._reserved[shard] += state.reserved
        self.page_tables[slot, :] = self.dump_page(slot)
        self.page_tables[slot, :map_n] = pages
        self.slots[slot] = state
        self.requests_admitted += 1
        if shared:
            self.prefix_hits += 1
            self.prefix_pages_shared += len(shared)
        self._bump_peaks()
        return state

    def ensure_capacity(self, slot: int, logical_block: int):
        """Map reserved pages so the slot's table covers `logical_block`
        (decode/verify write positions cross block boundaries lazily —
        the reservation made at admission guarantees the pages exist)."""
        s = self.slots[slot]
        shard = self.slot_shard(slot)
        assert logical_block < self.max_pages, (logical_block, self.max_pages)
        while len(s.pages) <= logical_block:
            assert s.reserved > 0, \
                f"slot {slot} writing block {logical_block} beyond its " \
                f"reserved budget ({len(s.pages)} pages mapped)"
            pg = self._free[shard].pop()
            assert self.refcount[pg] == 0
            s.reserved -= 1
            self._reserved[shard] -= 1
            self.refcount[pg] = 1
            s.pages.append(pg)
            self.page_tables[slot, len(s.pages) - 1] = pg
        self._bump_peaks()

    def rollback(self, slot: int, keep_blocks: int):
        """Speculative-decode rollback: unmap the slot's pages past the
        block holding the last ACCEPTED token, returning them to the free
        list and re-crediting the reservation.  Only private speculative
        pages are ever released — shared (refcounted > 1, prefix-indexed)
        pages sit strictly below the prompt end, which is below any
        accepted position, so `keep_blocks` can never reach them."""
        s = self.slots[slot]
        shard = self.slot_shard(slot)
        assert keep_blocks >= s.shared_pages, (keep_blocks, s.shared_pages)
        while len(s.pages) > keep_blocks:
            pg = s.pages.pop()
            assert self.refcount[pg] == 1 and pg not in self._page_key, \
                f"rollback would release shared page {pg}"
            self.refcount[pg] = 0
            self._free[shard].append(pg)
            s.reserved += 1
            self._reserved[shard] += 1
            self.page_tables[slot, len(s.pages)] = self.dump_page(slot)

    def _release_page(self, pg: int):
        """Decref one mapped page; at refcount 0 it leaves the prefix index
        and returns to its shard's free list."""
        self.refcount[pg] -= 1
        assert self.refcount[pg] >= 0
        if self.refcount[pg] == 0:
            key = self._page_key.pop(pg, None)
            if key is not None:
                copies = self._prefix.get(key)
                if copies is not None:
                    copies.discard(pg)
                    if not copies:
                        del self._prefix[key]
            self._free[self.page_shard(pg)].append(pg)

    def evict(self, slot: int):
        """Release the slot: decref its mapped pages and forfeit its
        remaining reservation; pages at refcount 0 return to the free list
        (and leave the prefix index — sharing is between co-resident
        requests only).  A swapped slot's host copies are dropped too."""
        s = self.slots[slot]
        if s is not None:
            self._reserved[self.slot_shard(slot)] -= s.reserved
            s.reserved = 0
            for pg in s.pages:
                self._release_page(pg)
        self._host.pop(slot, None)
        self.page_tables[slot, :] = self.dump_page(slot)
        self.slots[slot] = None

    # -- host-memory swap tier ---------------------------------------------

    def swap_out(self, slot: int):
        """Move ALL of a decoding slot's mapped pages to host memory.

        The device pages are released exactly like `evict` — shared prefix
        pages only decref, so a co-resident sharer keeps them on device —
        and the slot's reservation is returned to the pool (stashed in the
        host record; `swap_in` takes it back).  The slot keeps its index in
        phase "swapped": excluded from the decode batch but still owned, so
        its request id, sampled tokens, and position survive untouched."""
        s = self.slots[slot]
        assert s is not None and s.phase == "decode", (slot, s and s.phase)
        assert slot not in self._host and s.pages, (slot, s and s.pages)
        shard = self.slot_shard(slot)
        blob = jax.device_get(self._page_reader(
            self.cache, jnp.asarray(s.pages, jnp.int32)))
        self._host[slot] = {"blob": blob, "n": len(s.pages),
                            "reserved": s.reserved}
        self._reserved[shard] -= s.reserved
        s.reserved = 0
        for pg in s.pages:
            self._release_page(pg)
        s.pages = []
        s.shared_pages = 0
        s.phase = "swapped"
        self.page_tables[slot, :] = self.dump_page(slot)
        self.swap_out_count += 1
        self.pages_host_peak = max(self.pages_host_peak, self.pages_host)

    def can_resume(self, slot: int, prompt: np.ndarray,
                   graph_key=None) -> bool:
        """Whether `swap_in(slot)` would succeed right now: enough free
        un-reserved pages for the non-shared host pages PLUS the stashed
        reservation (re-admission must not over-promise the pool)."""
        rec = self._host[slot]
        shard = self.slot_shard(slot)
        shared = min(len(self.lookup_prefix(prompt, graph_key, shard)),
                     rec["n"])
        return (self.pages_available(shard)
                >= rec["n"] - shared + rec["reserved"])

    def swap_in(self, slot: int, prompt: np.ndarray, graph_key=None):
        """Bring a swapped slot's pages back on device and rejoin decode.

        Leading prefix pages still resident (content-addressed under
        `prompt` + `graph_key`) are reattached by refcount — bitwise
        identical to the host copies by construction — and only the rest
        is scattered back from the host blob, into freshly allocated
        pages.  The stashed reservation is restored, so the resumed slot
        is indistinguishable from one that never left."""
        s = self.slots[slot]
        assert s is not None and s.phase == "swapped", (slot, s and s.phase)
        assert self.can_resume(slot, prompt, graph_key), \
            f"swap_in({slot}) without capacity"
        rec = self._host.pop(slot)
        shard = self.slot_shard(slot)
        shared = self.lookup_prefix(prompt, graph_key, shard)[:rec["n"]]
        fresh = [self._free[shard].pop()
                 for _ in range(rec["n"] - len(shared))]
        pages = shared + fresh
        for pg in pages:
            self.refcount[pg] += 1
        if fresh:
            sl = (slice(None), slice(len(shared), None)) if self._scanned \
                else slice(len(shared), None)
            blob = {g: {k: jnp.asarray(a[sl]) for k, a in lv.items()}
                    for g, lv in rec["blob"].items()}
            self.cache = self._page_scatter(
                self.cache, blob, jnp.asarray(fresh, jnp.int32))
        s.pages = pages
        s.shared_pages = len(shared)
        s.reserved = rec["reserved"]
        self._reserved[shard] += s.reserved
        self.page_tables[slot, :] = self.dump_page(slot)
        self.page_tables[slot, :len(pages)] = pages
        s.phase = "decode"
        self.swap_in_count += 1
        self.register_prefix(slot, s.prompt_len, prompt, graph_key)
        self._bump_peaks()

    # -- copy-on-write guard ----------------------------------------------

    def ensure_writable(self, slot: int, logical_block: int) -> bool:
        """CoW guard: if the page the slot is about to write is shared
        (refcount > 1), move the slot onto a private copy first.  The
        admission policy keeps shared pages strictly below every write
        position, so this never fires in normal serving; it exists to make
        the sharing contract locally safe rather than globally argued."""
        s = self.slots[slot]
        if s is None or logical_block >= len(s.pages):
            return False
        old = s.pages[logical_block]
        if self.refcount[old] <= 1:
            return False
        shard = self.slot_shard(slot)
        if self.pages_available(shard) <= 0:
            raise RuntimeError("page pool exhausted during copy-on-write")
        new = self._free[shard].pop()
        self.cache = self._copier(self.cache, jnp.asarray(new, jnp.int32),
                                  jnp.asarray(old, jnp.int32))
        self.refcount[old] -= 1
        self.refcount[new] = 1
        s.pages[logical_block] = new
        if s.shared_pages > logical_block:
            s.shared_pages = logical_block
        self.page_tables[slot, logical_block] = new
        self._bump_peaks()
        return True

    # -- device writers ----------------------------------------------------

    PAGE_LEAVES = ("k", "v", "ks", "vs")   # page-dim-leading store keys

    def _copy_impl(self, cache, dst, src):
        out = {}
        for gname, leaves in cache.items():
            ng = {}
            for key, c in leaves.items():
                if key in self.PAGE_LEAVES and self._paged:
                    if self._scanned:
                        ng[key] = c.at[:, dst].set(c[:, src])
                    else:
                        ng[key] = c.at[dst].set(c[src])
                else:
                    ng[key] = c
            out[gname] = ng
        return out

    def _gather_pages_impl(self, cache, pages):
        """Read the page-store rows `pages` of every attn leaf (swap-out)."""
        out = {}
        for gname, leaves in cache.items():
            og = {}
            for key, c in leaves.items():
                if key in self.PAGE_LEAVES and self._paged:
                    og[key] = c[:, pages] if self._scanned else c[pages]
            out[gname] = og
        return out

    def _scatter_pages_impl(self, cache, blob, pages):
        """Write host page copies back into the rows `pages` (swap-in)."""
        out = {}
        for gname, leaves in cache.items():
            ng = dict(leaves)
            for key, a in blob[gname].items():
                c = leaves[key]
                if self._scanned:
                    ng[key] = c.at[:, pages].set(a.astype(c.dtype))
                else:
                    ng[key] = c.at[pages].set(a.astype(c.dtype))
            out[gname] = ng
        return out

    def _write_impl(self, cache, one, pages, blocks, slot):
        """Scatter a B=1 contiguous prefilled cache into the slot's pages
        (attn leaves) and the slot's row (recurrent leaves).

        one: attn K/V (1, Hkv, Sp, dh) with Sp a page multiple; `pages`
        and `blocks` are aligned (m,) int32 vectors — physical page id and
        source block index (prefix-shared pages are excluded by the
        caller, so shared content is never rewritten).  Quantized pools
        (int8 stores) quantize the selected blocks here, with the same
        absmax/127 per-(page, head) rule as the paged prefill writers, and
        scatter the scale rows alongside."""
        b = self.page_size
        out = {}
        for gname, leaves in cache.items():
            og, ng = one[gname], {}
            for key, c in leaves.items():
                if key in ("ks", "vs"):
                    continue          # written with their int8 pages below
                o = og[key]
                if key in ("k", "v"):
                    if self._scanned:      # c (R,P,H,b,d); o (R,1,H,Sp,d)
                        R, _, H, _, d = c.shape
                        blk = o[:, 0].reshape(R, H, -1, b, d) \
                               .transpose(0, 2, 1, 3, 4)       # (R,nb,H,b,d)
                        src = blk[:, blocks]
                        if key + "s" in leaves:
                            q, sc = Dec._quantize_pages(src)
                            ng[key] = c.at[:, pages].set(q.astype(c.dtype))
                            ng[key + "s"] = leaves[key + "s"] \
                                .at[:, pages].set(sc)
                        else:
                            ng[key] = c.at[:, pages].set(src.astype(c.dtype))
                    else:                  # c (P,H,b,d); o (1,H,Sp,d)
                        H, d = c.shape[1], c.shape[3]
                        blk = o[0].reshape(H, -1, b, d) \
                               .transpose(1, 0, 2, 3)          # (nb,H,b,d)
                        src = blk[blocks]
                        if key + "s" in leaves:
                            q, sc = Dec._quantize_pages(src)
                            ng[key] = c.at[pages].set(q.astype(c.dtype))
                            ng[key + "s"] = leaves[key + "s"] \
                                .at[pages].set(sc)
                        else:
                            ng[key] = c.at[pages].set(src.astype(c.dtype))
                else:
                    if self._scanned:      # c (R,cap,...); o (R,1,...)
                        ng[key] = c.at[:, slot].set(o[:, 0].astype(c.dtype))
                    else:
                        ng[key] = c.at[slot].set(o[0].astype(c.dtype))
            out[gname] = ng
        return out

    def write_prefill(self, slot: int, one_request_cache):
        """Write a one-shot B=1 prefilled cache through the slot's page
        table, skipping prefix-shared pages."""
        s = self.slots[slot]
        b = self.page_size
        # source blocks available in the contiguous prefill
        leaf = next((l["k"] for l in one_request_cache.values() if "k" in l),
                    None)
        nb_src = (leaf.shape[2 + self._scanned] // b) if leaf is not None \
            else 0
        lo, hi = s.shared_pages, min(len(s.pages), nb_src)
        pages = jnp.asarray([s.pages[j] for j in range(lo, hi)]
                            or [self.dump_page(slot)], jnp.int32)
        blocks = jnp.asarray(list(range(lo, hi)) or [0], jnp.int32)
        self.cache = self._writer(self.cache, one_request_cache, pages,
                                  blocks, jnp.asarray(slot, jnp.int32))

    # -- per-step device arrays -------------------------------------------

    def position_vector(self) -> np.ndarray:
        """(capacity,) int32 of per-slot write positions; idle/prefilling
        slots are pinned to max_len - 1 (in-bounds; their table rows are
        zeroed for the step so the garbage write lands on the dump page)."""
        pos = np.full((self.capacity,), self.max_len - 1, np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and s.phase == "decode":
                pos[i] = s.pos
        return pos

    def _local_ids(self, rows: np.ndarray, slots) -> np.ndarray:
        """GLOBAL page ids -> shard-LOCAL ids, row-wise (a shard_map body
        indexes its local page-store slice, whose row 0 is its dump)."""
        out = rows.copy()
        for r, slot in enumerate(slots):
            out[r] -= self.slot_shard(slot) * self.pages_per_shard
        return out

    def table_matrix(self) -> np.ndarray:
        """(capacity, max_pages) int32 for the batched decode step: live
        rows for decoding slots, dump-page rows for everyone else — in
        shard-LOCAL page ids (global == local when data_shards == 1)."""
        pt = self.page_tables.copy()
        decoding = set(self.decode_slots())
        for i in range(self.capacity):
            if i not in decoding:
                pt[i] = self.dump_page(i)
        return self._local_ids(pt, range(self.capacity))

    def table_row(self, slot: int) -> np.ndarray:
        """(1, max_pages) int32 page-table row for a prefill chunk, in
        shard-LOCAL page ids."""
        return self._local_ids(self.page_tables[slot:slot + 1], [slot])

    # -- accounting --------------------------------------------------------

    def reset_stats(self):
        """Zero the cumulative counters (benchmarks: after warmup)."""
        self.peak_pages_in_use = self.pages_in_use
        self.peak_pages_per_shard = [self.pages_in_use_shard(d)
                                     for d in range(self.data_shards)]
        self.prefix_hits = 0
        self.prefix_pages_shared = 0
        self.requests_admitted = 0
        self.swap_out_count = 0
        self.swap_in_count = 0
        self.pages_host_peak = self.pages_host

    def kv_bytes_per_page(self) -> int:
        n = 0
        for leaves in jax.tree.leaves(
                {g: {k: v for k, v in lv.items() if k in self.PAGE_LEAVES}
                 for g, lv in self.cache.items()}):
            n += leaves.size * leaves.dtype.itemsize // self.num_pages
        return n
