"""Slot-based continuous batching over the model-zoo cache families.

A `SlotPool` owns ONE fixed-capacity device cache tree (attention KV,
mamba state, rwkv state — whatever `models/decode.cache_spec` builds for
the config) whose batch dim is a pool of `capacity` slots.  Requests are
admitted into free slots at step boundaries by overwriting a slot's rows
with a freshly prefilled single-request cache, and evicted by simply
marking the slot free — the stale rows are dead weight until the next
admit overwrites them, so admission/eviction never reshapes or re-jits
anything.

Padding-free accounting: every slot carries its own `pos`, and
`models/decode.decode_step` takes the whole (capacity,) position vector,
so one decode step serves heterogeneous prompt lengths; idle slots
compute garbage that nothing reads.

Cache layout note: for scanned configs (`cfg.scan_layers`, repeats > 1)
the per-group leaves are (repeats, B, ...) — batch is dim 1 — while
unscanned leaves are (B, ...).  The slot writer handles both.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.models import decode as Dec


@dataclasses.dataclass
class SlotState:
    """Host-side bookkeeping for one occupied slot."""
    request_id: int
    pos: int                   # cache position the NEXT decode step writes
    generated: int             # tokens emitted so far
    max_new: int
    stop_token: Optional[int]
    tokens: list               # emitted tokens (host ints)
    prompt_len: int
    admit_step: int            # engine step counter at admission (TTFT)


class SlotPool:
    """Fixed-capacity slot pool over one device cache tree."""

    def __init__(self, cfg, capacity: int, max_len: int):
        self.cfg, self.capacity, self.max_len = cfg, capacity, max_len
        self.cache = Dec.cache_spec(cfg, capacity, max_len, abstract=False)
        self._scanned = cfg.scan_layers and cfg.repeats > 1
        self.slots: list = [None] * capacity       # SlotState | None
        self._writer = self._make_writer()

    # -- occupancy ---------------------------------------------------------

    def free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_slots(self):
        return [i for i, s in enumerate(self.slots) if s is not None]

    # -- admission / eviction ---------------------------------------------

    def _make_writer(self):
        scanned = self._scanned

        def write(pool, one, slot):
            if scanned:                  # leaves (repeats, B, ...): batch dim 1
                return jax.tree.map(
                    lambda c, n: c.at[:, slot].set(n[:, 0]), pool, one)
            return jax.tree.map(lambda c, n: c.at[slot].set(n[0]), pool, one)

        return jax.jit(write, donate_argnums=(0,))

    def admit(self, slot: int, one_request_cache, state: SlotState):
        """Overwrite `slot`'s cache rows with a B=1 prefilled cache."""
        assert self.slots[slot] is None, f"slot {slot} occupied"
        assert state.pos + state.max_new <= self.max_len + 1, \
            f"request needs {state.pos + state.max_new} > max_len {self.max_len}"
        self.cache = self._writer(self.cache, one_request_cache, slot)
        self.slots[slot] = state

    def evict(self, slot: int):
        self.slots[slot] = None

    # -- per-step device arrays -------------------------------------------

    def position_vector(self) -> np.ndarray:
        """(capacity,) int32 of per-slot write positions (idle slots pinned
        to max_len - 1: in-bounds, overwritten at their next admit)."""
        pos = np.full((self.capacity,), self.max_len - 1, np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                pos[i] = s.pos
        return pos
