"""Tracing-time sharding annotations.

Launchers install a mesh (+ §Perf optimization level) around tracing with
`active_mesh`; model code calls `constrain(x, logical_axes)` at collective
boundaries.  With no active mesh every annotation is a no-op, so the same
model functions run unmodified in single-device tests and examples.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding

from repro.dist import sharding as Sh

# (mesh, opt_level) stack; tracing is single-threaded so a plain list works
_ACTIVE: list = []


@contextlib.contextmanager
def active_mesh(mesh, opt_level: int = 0):
    """Install `mesh` as the constraint target while tracing a step fn."""
    _ACTIVE.append((mesh, opt_level))
    try:
        yield mesh
    finally:
        _ACTIVE.pop()


def current_mesh():
    return _ACTIVE[-1][0] if _ACTIVE else None


def opt_level() -> int:
    """§Perf optimization level of the innermost active mesh (0 = baseline)."""
    return _ACTIVE[-1][1] if _ACTIVE else 0


def data_shards() -> int:
    """Data-parallel way-count (pod x data) of the active mesh, 1 if none."""
    mesh = current_mesh()
    return Sh.data_shard_count(mesh) if mesh is not None else 1


def constrain(x, logical_axes):
    """Sharding hint: constrain `x` to the rules-engine spec for its axes.

    Identity when no mesh is active (eager tests / examples) or when the
    rules produce full replication anyway.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = Sh.spec_for(x.shape, logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
