"""Distribution substrate: logical-axis sharding rules + tracing-time
annotations.

  sharding.py — the rules engine mapping logical axis names (vocab, embed,
                heads, seq, batch, ...) to mesh axes, with divisibility
                fallback and no-reuse guarantees;
  annotate.py — `constrain` (sharding hints inside traced functions),
                `active_mesh` (the context the launchers install), and the
                opt_level / data_shards knobs read by §Perf code paths.
"""
