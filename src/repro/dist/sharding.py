"""Logical-axis -> mesh-axis sharding rules engine.

Every parameter / activation / cache dim carries a *logical* axis name
(models/params.py docstring lists the vocabulary).  `spec_for` maps a
concrete shape + logical axes to a PartitionSpec for a given mesh under
three invariants:

  1. divisibility — a dim is only sharded over mesh axes whose combined
     size divides it; otherwise it falls back to replication (this is what
     makes elastic downscale safe: a smaller mesh degrades, never fails);
  2. no reuse — a mesh axis is consumed by at most one dim of a spec;
  3. preference order — each logical axis has an ordered list of mesh-axis
     candidates (combined first, then singly), so e.g. `batch` soaks up
     (pod, data) when both exist and `seq` picks up whatever data-parallel
     capacity the batch could not use (long-context sequence sharding).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec

# ordered mesh-axis candidates per logical axis name.  A tuple with more
# than one entry is first tried *combined* (product divisibility), then
# each member singly, left to right.
_PREFS = {
    "batch": ("pod", "data"),
    "capacity": ("pod", "data"),     # MoE shard-local dispatch buffers
    "seq": ("data", "model"),        # sequence sharding for long context
    "vocab": ("model",),
    "embed": ("data",),              # FSDP-style weight sharding
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "mlp": ("model",),
    # paged-KV physical page dim: split along DATA.  Each data shard owns an
    # independent sub-pool (its own dump page, free list, and local page-id
    # space), and every slot's page-table row only ever references its own
    # shard's sub-pool — a page-table lookup never crosses the data axis.
    # kv_heads carry the model parallelism of the paged leaves.
    "pages": ("data",),
    # per-slot serving operands (page tables, positions, tokens): the slot
    # roster is partitioned over data like the pages it maps.
    "slots": ("data",),
    # never sharded: layers (scan dim), conv, state, head_dim
}


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def abstract_mesh(axis_sizes, axis_names):
    """Version-agnostic AbstractMesh constructor (the ctor signature changed
    across jax releases)."""
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def _assign(dim: int, candidates: tuple, sizes: dict):
    """First candidate group whose combined size divides `dim`, else None."""
    groups = []
    if len(candidates) > 1:
        groups.append(candidates)
    groups.extend((c,) for c in candidates)
    for grp in groups:
        prod = 1
        for a in grp:
            prod *= sizes[a]
        if prod > 1 and dim % prod == 0:
            return grp
    return None


def spec_for(shape, axes, mesh) -> PartitionSpec:
    """PartitionSpec for one array: shape + logical axis names + mesh."""
    assert len(shape) == len(axes), (shape, axes)
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, name in zip(shape, axes):
        cand = tuple(a for a in _PREFS.get(name, ())
                     if a in sizes and a not in used)
        grp = _assign(dim, cand, sizes) if (name and cand) else None
        if grp is None:
            parts.append(None)
        else:
            used.update(grp)
            parts.append(grp if len(grp) > 1 else grp[0])
    return PartitionSpec(*parts)


def partition_tree(spec_tree, mesh):
    """P-spec tree -> PartitionSpec tree (params, optimizer state, ...)."""
    from repro.models.params import map_leaves
    return map_leaves(lambda p: spec_for(p.shape, p.axes, mesh), spec_tree)


def batch_pspec(shape, mesh) -> PartitionSpec:
    """Spec for a batch-leading activation/token array (dim 0 = batch)."""
    return spec_for(shape, ("batch",) + (None,) * (len(shape) - 1), mesh)


def data_shard_count(mesh) -> int:
    """Combined size of the data-parallel axes (pod x data) of a mesh."""
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in ("pod", "data"):
        n *= sizes.get(a, 1)
    return n


def validate_serving_mesh(cfg, mesh, capacity: int,
                          num_pages=None) -> tuple:
    """Validate a (data, model) mesh for the paged serving path.

    Mesh-parallel decode splits KV heads (and the query-head groups that
    read them) along `model` and the slot roster / page sub-pools along
    `data`; unlike the elastic training rules (which silently degrade to
    replication), serving sharding is an explicit contract — an indivisible
    head count or slot roster is a configuration error, not a fallback.

    Returns (data, model) sizes."""
    sizes = mesh_axis_sizes(mesh)
    unknown = set(sizes) - {"data", "model"}
    if unknown:
        raise ValueError(
            f"serving mesh supports axes (data, model); got {unknown}")
    data = sizes.get("data", 1)
    model = sizes.get("model", 1)
    if cfg.num_kv_heads % model != 0:
        raise ValueError(
            f"model axis {model} must divide num_kv_heads "
            f"{cfg.num_kv_heads}")
    if cfg.num_heads % model != 0:
        raise ValueError(
            f"model axis {model} must divide num_heads {cfg.num_heads}")
    if capacity % data != 0:
        raise ValueError(
            f"data axis {data} must divide engine capacity {capacity}")
    if num_pages is not None and num_pages % data != 0:
        raise ValueError(
            f"data axis {data} must divide num_pages {num_pages}")
    return data, model


def serving_cache_pspecs(cfg, B, max_len, num_pages, kv_dtype=None):
    """PartitionSpec tree for the paged serving cache under shard_map.

    Unlike `spec_for` (preference order + divisibility fallback), these are
    the EXACT specs the sharded decode/prefill executables require: paged
    K/V leaves split pages over `data` and kv heads over `model`; recurrent
    per-slot leaves split their slot dim over `data`.  Quantized pools'
    scale leaves (`ks`/`vs`, axes (pages, kv_heads)) follow the same rule
    as the pages they scale.  Callers must have passed
    `validate_serving_mesh` first."""
    from repro.models import decode as Dec
    axes_tree = Dec.cache_logical_axes(cfg, B, max_len, num_pages=num_pages,
                                       kv_dtype=kv_dtype)
    mapping = {"pages": "data", "kv_heads": "model", "batch": "data"}

    def to_spec(axes):
        return PartitionSpec(*[mapping.get(a) for a in axes])

    return {grp: {k: to_spec(a) for k, a in leaves.items()}
            for grp, leaves in axes_tree.items()}
