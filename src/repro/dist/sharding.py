"""Logical-axis -> mesh-axis sharding rules engine.

Every parameter / activation / cache dim carries a *logical* axis name
(models/params.py docstring lists the vocabulary).  `spec_for` maps a
concrete shape + logical axes to a PartitionSpec for a given mesh under
three invariants:

  1. divisibility — a dim is only sharded over mesh axes whose combined
     size divides it; otherwise it falls back to replication (this is what
     makes elastic downscale safe: a smaller mesh degrades, never fails);
  2. no reuse — a mesh axis is consumed by at most one dim of a spec;
  3. preference order — each logical axis has an ordered list of mesh-axis
     candidates (combined first, then singly), so e.g. `batch` soaks up
     (pod, data) when both exist and `seq` picks up whatever data-parallel
     capacity the batch could not use (long-context sequence sharding).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec

# ordered mesh-axis candidates per logical axis name.  A tuple with more
# than one entry is first tried *combined* (product divisibility), then
# each member singly, left to right.
_PREFS = {
    "batch": ("pod", "data"),
    "capacity": ("pod", "data"),     # MoE shard-local dispatch buffers
    "seq": ("data", "model"),        # sequence sharding for long context
    "vocab": ("model",),
    "embed": ("data",),              # FSDP-style weight sharding
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "mlp": ("model",),
    # paged-KV physical page dim: REPLICATE.  Page ids are host-assigned
    # request metadata — splitting them over a mesh axis would turn every
    # page-table lookup into a cross-shard gather; kv_heads/embed keep
    # carrying the model parallelism of the paged leaves instead.
    "pages": (),
    # never sharded: layers (scan dim), conv, state, head_dim
}


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def abstract_mesh(axis_sizes, axis_names):
    """Version-agnostic AbstractMesh constructor (the ctor signature changed
    across jax releases)."""
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def _assign(dim: int, candidates: tuple, sizes: dict):
    """First candidate group whose combined size divides `dim`, else None."""
    groups = []
    if len(candidates) > 1:
        groups.append(candidates)
    groups.extend((c,) for c in candidates)
    for grp in groups:
        prod = 1
        for a in grp:
            prod *= sizes[a]
        if prod > 1 and dim % prod == 0:
            return grp
    return None


def spec_for(shape, axes, mesh) -> PartitionSpec:
    """PartitionSpec for one array: shape + logical axis names + mesh."""
    assert len(shape) == len(axes), (shape, axes)
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, name in zip(shape, axes):
        cand = tuple(a for a in _PREFS.get(name, ())
                     if a in sizes and a not in used)
        grp = _assign(dim, cand, sizes) if (name and cand) else None
        if grp is None:
            parts.append(None)
        else:
            used.update(grp)
            parts.append(grp if len(grp) > 1 else grp[0])
    return PartitionSpec(*parts)


def partition_tree(spec_tree, mesh):
    """P-spec tree -> PartitionSpec tree (params, optimizer state, ...)."""
    from repro.models.params import map_leaves
    return map_leaves(lambda p: spec_for(p.shape, p.axes, mesh), spec_tree)


def batch_pspec(shape, mesh) -> PartitionSpec:
    """Spec for a batch-leading activation/token array (dim 0 = batch)."""
    return spec_for(shape, ("batch",) + (None,) * (len(shape) - 1), mesh)


def data_shard_count(mesh) -> int:
    """Combined size of the data-parallel axes (pod x data) of a mesh."""
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in ("pod", "data"):
        n *= sizes.get(a, 1)
    return n
