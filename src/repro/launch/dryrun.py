"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective statistics.

  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --cells yi-6b:train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --all

Results accumulate in experiments/dryrun_<mesh>.json (one JSON object per
cell) and feed EXPERIMENTS.md §Dry-run and §Roofline.

NOTE: the XLA_FLAGS line below MUST run before any other import — jax locks
the device count at first init.  Only this entry point sets it; tests and
benchmarks see the real single device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.launch import steps
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments"

# v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link (ICI)

_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def parse_collective_bytes(hlo_text: str):
    """Per-chip collective bytes by op kind, from partitioned HLO.

    Shapes in the post-SPMD module are per-partition, so summing result
    bytes gives per-chip traffic.  all-reduce counted 2x (ring =
    reduce-scatter + all-gather); reduce-scatter counted by operand size
    (= result x group), approximated via the larger operand when printed,
    else result bytes.  '-done' ops are skipped to avoid double counting.
    """
    out = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3).lower()
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dtype]
        if kind == "all-reduce":
            b *= 2
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg, seq, batch, mode):
    """Analytic 6*N_active*D (training) or 2*N_active*D (inference fwd)."""
    from repro.models.params import param_count
    from repro.models import model as M
    spec = M.param_spec(cfg)
    n = param_count(spec)
    if cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        moe_layers = sum(1 for ls in cfg.layer_pattern if ls.moe) * cfg.repeats
        per_moe = cfg.moe.num_experts * 3 * cfg.d_model * cfg.moe.d_ff
        n_moe = moe_layers * per_moe
        n = n - n_moe + n_moe * (k / e)
    tokens = batch * (seq if mode != "decode" else 1)
    if cfg.kind == "encdec" and mode != "decode":
        tokens = batch * (seq + cfg.dec_len)
    mult = 6 if mode == "train" else 2
    return mult * n * tokens


def run_cell(arch, shape, mesh, mesh_name, microbatches=8, opt_level=0):
    """Lower + compile one cell; derive roofline terms with trip-count-aware
    HLO accounting (launch/hlo_cost.py — cost_analysis() counts while-loop
    bodies once, which undercounts scanned layers by ~layers x microbatches).
    """
    from repro.launch import hlo_cost

    t0 = time.time()
    built = steps.build_step(arch, shape, mesh, microbatches=microbatches,
                             opt_level=opt_level)
    jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                 out_shardings=built["out_shardings"],
                 donate_argnums=built["donate"])
    lowered = jf.lower(*built["abstract_args"])
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    parsed = hlo_cost.analyze(hlo_text)

    chips = mesh.devices.size
    seq, gbatch, mode = configs.SHAPES[shape]
    flops_dev = parsed["flops"]
    bytes_dev = parsed["bytes"]
    coll = parsed["collectives"]
    mf = model_flops(built["cfg"], seq, gbatch, mode)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "mode": mode, "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "peak_memory_in_bytes", 0) or
                        (getattr(mem, "argument_size_in_bytes", 0)
                         + getattr(mem, "output_size_in_bytes", 0)
                         + getattr(mem, "temp_size_in_bytes", 0))),
        },
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "xla_cost_analysis_once": {           # uncorrected, for reference
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "model_flops_global": mf,
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll["total"] / LINK_BW,
        },
    }
    r = rec["roofline"]
    dom = max((k for k in ("compute_s", "memory_s", "collective_s")),
              key=lambda k: r[k])
    ideal = mf / chips / PEAK_FLOPS
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    rec["roofline"]["dominant"] = dom
    rec["roofline"]["ideal_compute_s"] = ideal
    rec["roofline"]["fraction_of_roofline"] = (ideal / bound) if bound else None
    rec["model_vs_hlo_flops"] = (mf / (flops_dev * chips)) if flops_dev else None
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--cells", nargs="*", default=None,
                    help="arch:shape pairs; default = all 40")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--opt", type=int, default=0,
                    help="beyond-paper optimization level (see §Perf)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    mesh_name = args.mesh + (f"-opt{args.opt}" if args.opt else "") + (
        f"-{args.tag}" if args.tag else "")
    cells = ([tuple(c.split(":")) for c in args.cells] if args.cells
             else configs.all_cells())

    RESULTS_DIR.mkdir(exist_ok=True)
    out_path = RESULTS_DIR / f"dryrun_{mesh_name}.json"
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    for arch, shape in cells:
        key = f"{arch}:{shape}"
        print(f"=== {key} on {args.mesh} ({mesh.devices.size} chips) ===",
              flush=True)
        try:
            rec = run_cell(arch, shape, mesh, mesh_name, args.microbatches,
                           args.opt)
            r = rec["roofline"]
            print(f"  ok in {rec['compile_s']}s  peak/dev="
                  f"{rec['bytes_per_device']['peak']/2**30:.2f}GiB  "
                  f"compute={r['compute_s']*1e3:.1f}ms "
                  f"memory={r['memory_s']*1e3:.1f}ms "
                  f"collective={r['collective_s']*1e3:.1f}ms "
                  f"dominant={r['dominant']}", flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAIL: {rec['error']}", flush=True)
        results[key] = rec
        out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells green -> {out_path}")


if __name__ == "__main__":
    main()
