"""train_step / serve_step factories with full sharding plumbing.

`build_step(cfg, mesh, mode, ...)` returns (fn, in_shardings, out_shardings,
abstract_args) ready for `jax.jit(...).lower(*abstract_args).compile()` —
this is the single entry point the dry-run, the real trainer, and the
benchmarks all share.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist import sharding as Sh
from repro.models import decode as Dec
from repro.models import model as M
from repro.models.params import abstract_params
from repro.optim import optimizers as Opt
from repro.optim import schedules

F32 = jnp.float32
REPL = PartitionSpec()


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------

def make_train_step(cfg: M.ModelConfig, opt: Opt.Optimizer, microbatches: int = 1,
                    grad_sync=None):
    """`grad_sync(grads, err) -> (synced, new_err)` hooks a cross-pod
    gradient sync (optim/compression.compressed_grad_sync) between the
    backward pass and the optimizer; the error-feedback residual rides in
    `state["grad_err"]` (same tree as params)."""
    def loss_of(params, batch):
        return M.loss_fn(params, cfg, batch)

    def train_step(state, batch):
        params, opt_state, step = state["params"], state["opt"], state["step"]
        if microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def micro(carry, b):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_of)(params, b)
                gacc = jax.tree.map(lambda a, x: a + x.astype(F32), gacc, g)
                return (gacc, lacc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros((), F32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        new_err = None
        if grad_sync is not None:
            grads, new_err = grad_sync(grads, state["grad_err"])
        new_params, new_opt, metrics = opt.update(grads, opt_state, params, step)
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        if new_err is not None:
            new_state["grad_err"] = new_err
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def make_distill_step(student_cfg: M.ModelConfig, teacher_cfg: M.ModelConfig,
                      opt: Opt.Optimizer):
    """Distill a draft LM from a frozen teacher: per-position
    KL(teacher || student) over teacher-forced CLM positions
    (`models/model.chunked_kl_loss`), the objective that maximizes the
    draft's greedy acceptance rate in speculative serving.  The student
    backward runs the same custom_vjp attention path as `make_train_step`
    (impl="pallas" fused kernels); the teacher forward is grad-free.

    distill_step(state, teacher_params, batch) -> (state, metrics) with
    metrics["agree"] = teacher/student argmax agreement fraction."""
    assert student_cfg.vocab_size == teacher_cfg.vocab_size, \
        (student_cfg.vocab_size, teacher_cfg.vocab_size)

    def distill_step(state, teacher_params, batch):
        params, opt_state, step = state["params"], state["opt"], state["step"]
        h_t, _ = M.hidden_states(teacher_params, teacher_cfg, batch)
        w_t = M._unembed_weight(teacher_params, teacher_cfg)
        h_t, w_t = jax.lax.stop_gradient((h_t, w_t))

        def loss_of(p):
            h_s, aux = M.hidden_states(p, student_cfg, batch)
            w_s = M._unembed_weight(p, student_cfg)
            kl, agree = M.chunked_kl_loss(
                h_s, w_s, h_t, w_t, student_cfg.loss_chunk,
                vocab_real=student_cfg.vocab_size)
            return kl + student_cfg.aux_loss_weight * aux, agree

        (loss, agree), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)
        new_params, new_opt, metrics = opt.update(grads, opt_state, params,
                                                  step)
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        return new_state, dict(metrics, loss=loss, agree=agree)

    return distill_step


def make_optimizer(cfg_name: str = "", kind: str = "adamw",
                   schedule: str = "cosine", peak_lr: float = 1e-4,
                   warmup: int = 10_000, total: int = 100_000):
    lr_fn = schedules.by_name(schedule, peak_lr, warmup, total)
    return Opt.by_name(kind, lr_fn)


def state_pspec_tree(cfg: M.ModelConfig, opt: Opt.Optimizer, mesh):
    pspec = M.param_spec(cfg)
    return {
        "params": Sh.partition_tree(pspec, mesh),
        "opt": Sh.partition_tree(opt.state_spec(pspec), mesh),
        "step": REPL,
    }


def abstract_state(cfg: M.ModelConfig, opt: Opt.Optimizer):
    pspec = M.param_spec(cfg)
    return {
        "params": abstract_params(pspec, cfg.dtype),
        "opt": abstract_params(opt.state_spec(pspec)),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def batch_pspecs(batch_specs, mesh):
    return {k: Sh.batch_pspec(v.shape, mesh) for k, v in batch_specs.items()}


# --------------------------------------------------------------------------
# serve (prefill / decode)
# --------------------------------------------------------------------------

def make_prefill_step(cfg: M.ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return Dec.prefill(params, cfg, batch, max_len)
    return prefill_step


def make_serve_step(cfg: M.ModelConfig):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = Dec.decode_step(params, cfg, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache
    return serve_step


def cache_pspecs(cfg: M.ModelConfig, mesh, B, max_len, enc_len=0):
    shapes = Dec.cache_spec(cfg, B, max_len, enc_len)
    axes = Dec.cache_logical_axes(cfg, B, max_len, enc_len)
    return jax.tree.map(
        lambda s, ax: Sh.spec_for(s.shape, ax, mesh),
        shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or (
            isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)))


# --------------------------------------------------------------------------
# the single entry point used by dryrun / trainer / benchmarks
# --------------------------------------------------------------------------

def _ns(mesh, tree):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def _with_mesh(fn, mesh, opt_level=0):
    """Activate the annotation mesh (+ optimization level) during tracing."""
    from repro.dist.annotate import active_mesh

    @functools.wraps(fn)
    def wrapped(*args):
        with active_mesh(mesh, opt_level):
            return fn(*args)
    return wrapped


def build_step(arch: str, shape: str, mesh, *, microbatches: int = 8,
               donate: bool = True, opt_level: int = 0):
    """Returns dict(fn, in_shardings, out_shardings, abstract_args, donate)."""
    from repro import configs

    import dataclasses as _dc
    cfg = configs.config_for_cell(arch, shape)
    if opt_level >= 1:
        # §Perf: pad the vocab to a shardable multiple (kills the per-chunk
        # unembed all-gather for 50358/92553/122753-sized vocabs)
        cfg = _dc.replace(cfg, vocab_pad=256)
    mode, specs = configs.input_specs(arch, shape)
    seq, gbatch, _ = configs.SHAPES[shape]

    if mode == "train":
        opt = make_optimizer(kind=configs.optimizer_for(arch),
                             schedule=configs.schedule_for(arch))
        mb = max(1, min(microbatches, gbatch))
        fn = _with_mesh(make_train_step(cfg, opt, microbatches=mb), mesh, opt_level)
        st_ps = state_pspec_tree(cfg, opt, mesh)
        b_ps = batch_pspecs(specs, mesh)
        in_sh = (_ns(mesh, st_ps), _ns(mesh, b_ps))
        out_sh = (_ns(mesh, st_ps), None)
        args = (abstract_state(cfg, opt), specs)
        return dict(fn=fn, in_shardings=in_sh, out_shardings=out_sh,
                    abstract_args=args, donate=(0,) if donate else (),
                    cfg=cfg, mode=mode)

    pspec = M.param_spec(cfg)
    p_ps = Sh.partition_tree(pspec, mesh)
    p_abs = abstract_params(pspec, cfg.dtype)

    if mode == "prefill":
        fn = _with_mesh(make_prefill_step(
            cfg, max_len=(cfg.dec_len if cfg.kind == "encdec" else seq)),
            mesh, opt_level)
        b_ps = batch_pspecs(specs, mesh)
        in_sh = (_ns(mesh, p_ps), _ns(mesh, b_ps))
        args = (p_abs, specs)
        return dict(fn=fn, in_shardings=in_sh, out_shardings=None,
                    abstract_args=args, donate=(), cfg=cfg, mode=mode)

    # decode
    fn = _with_mesh(make_serve_step(cfg), mesh, opt_level)
    enc_len = seq if cfg.kind == "encdec" else 0
    max_len = cfg.dec_len if cfg.kind == "encdec" else seq
    c_ps = cache_pspecs(cfg, mesh, gbatch, max_len, enc_len)
    tok_ps = Sh.batch_pspec((gbatch, 1), mesh)
    in_sh = (_ns(mesh, p_ps), _ns(mesh, c_ps), _ns(mesh, tok_ps),
             NamedSharding(mesh, REPL))
    out_sh = (_ns(mesh, Sh.batch_pspec((gbatch,), mesh)), None, _ns(mesh, c_ps))
    args = (p_abs, specs["cache"], specs["tokens"], specs["pos"])
    return dict(fn=fn, in_shardings=in_sh, out_shardings=out_sh,
                abstract_args=args, donate=(1,) if donate else (),
                cfg=cfg, mode=mode)
