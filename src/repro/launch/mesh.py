"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state.  Shapes follow the assignment:

  single pod : (16, 16)      axes (data, model)   — 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16)   axes (pod, data, model) — 512 chips

`pod` is data-parallel across ICI-disjoint pods (gradient sync over DCN);
`data` is in-pod DP/FSDP (+ sequence sharding for long-context serving);
`model` is tensor/expert parallelism.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
