"""Trip-count-aware cost accounting over compiled (post-SPMD) HLO text.

`compiled.cost_analysis()` counts while-loop bodies ONCE — useless for
scanned layers / microbatch accumulation / chunked losses.  XLA, however,
annotates every counted loop with `backend_config={"known_trip_count":...}`.
This module re-derives the three roofline numerators properly:

  * flops            — 2 * prod(result dims) * prod(contracting dims) for
                       every `dot` (and convolution), x the product of
                       enclosing loop trip counts;
  * bytes            — HBM traffic model: for every *materialized* op
                       (instructions of the entry / while computations —
                       fusion internals excluded) operand + result bytes,
                       x trip counts.  This is an upper-ish bound that
                       matches XLA's buffer-materialization boundaries;
  * collective bytes — per-chip bytes by collective kind (shapes in the
                       partitioned module are per-partition), x trip counts;
                       all-reduce counted 2x (ring = RS + AG).

All shapes are per-device, so derived seconds are per-chip directly.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPND = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLLS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def _shape_bytes(text):
    """Sum byte sizes of every TYPE[dims] group in `text`."""
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text):
    m = _SHAPE.search(text)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


class _Comp:
    __slots__ = ("name", "instrs", "shapes", "_param_reads")

    def __init__(self, name):
        self.name = name
        self.instrs = []        # (name, rhs)
        self.shapes = {}        # instr name -> result-shape string
        self._param_reads = None

    def param_read_bytes(self):
        """Effective bytes read per parameter index, accounting for fusion
        bodies that only dynamic-slice a big operand (e.g. scan-over-layers
        slicing one layer out of stacked params): charge the slice, not the
        buffer."""
        if self._param_reads is not None:
            return self._param_reads
        out = {}
        params = {}
        for iname, rhs in self.instrs:
            if " parameter(" in rhs:
                idx = int(rhs.split(" parameter(", 1)[1].split(")", 1)[0])
                params[iname] = idx
                out[idx] = _shape_bytes(rhs.split("(", 1)[0])
        # find each param's uses
        for pname, idx in params.items():
            uses = []
            for iname, rhs in self.instrs:
                if iname == pname or "(" not in rhs:
                    continue
                args = rhs.split("(", 1)[1].split(")", 1)[0]
                if pname in _OPND.findall(args):
                    uses.append((iname, rhs, _OPND.findall(args)))
            if uses and all(" dynamic-slice(" in rhs for _, rhs, _a in uses):
                out[idx] = sum(_shape_bytes(rhs.split("(", 1)[0])
                               for _, rhs, _a in uses)
            elif uses and all(
                    " dynamic-update-slice(" in rhs and a and a[0] == pname
                    for _, rhs, a in uses):
                # param is only the in-place target of a DUS: no read traffic
                out[idx] = 0
        self._param_reads = out
        return out

    def dus_root_bytes(self):
        """If the fusion root is (a bitcast/convert of) a dynamic-update-slice,
        the fusion writes in place: return the update-slice bytes, else None."""
        dus_updates = []
        for iname, rhs in self.instrs:
            if " dynamic-update-slice(" in rhs:
                args = rhs.split("(", 1)[1].split(")", 1)[0]
                ops = _OPND.findall(args)
                if len(ops) >= 2:
                    dus_updates.append(_shape_bytes(self.shapes.get(ops[1], "")))
        if dus_updates:
            return sum(dus_updates)
        return None


def parse_computations(text):
    comps = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if cur is None or not line.startswith((" ", "\t")):
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Comp(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            name, rhs = mi.group(1), mi.group(2)
            cur.instrs.append((name, rhs))
            # result shape(s) = rhs up to the op name: take text before '('
            head = rhs.split("(", 1)[0]
            cur.shapes[name] = head
    return comps, entry


def _callees(rhs):
    """Yield (callee_name, kind) for computations referenced by this instr."""
    for attr, kind in (("body=", "while_body"), ("condition=", "while_cond"),
                       ("calls=", "call"), ("to_apply=", "call"),
                       ("branch_computations=", "call")):
        i = rhs.find(attr)
        if i < 0:
            continue
        tail = rhs[i + len(attr):]
        if tail.startswith("{"):
            names = _OPND.findall(tail[:tail.index("}")])
        else:
            m = _OPND.match(tail)
            names = [m.group(1)] if m else []
        for n in names:
            yield n, kind


def compute_multipliers(comps, entry):
    """Computation name -> total execution multiplier (trip-count products)."""
    mult = defaultdict(float)
    mult[entry] = 1.0
    # iterate to fixpoint over the call DAG (cheap: few hundred comps)
    for _ in range(64):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if not m:
                continue
            for iname, rhs in comp.instrs:
                trip = 1.0
                tm = _TRIP.search(rhs)
                if tm:
                    trip = float(tm.group(1))
                for callee, kind in _callees(rhs):
                    w = trip if kind in ("while_body", "while_cond") else 1.0
                    new[callee] += m * w
        new[entry] = 1.0
        if dict(new) != dict(mult):
            mult = new
            changed = True
        if not changed:
            break
    return mult


def _is_fusion_internal(comps, entry):
    """Comps reached only via calls= / to_apply= (not materialized bodies)."""
    internal = set()
    for comp in comps.values():
        for _, rhs in comp.instrs:
            for callee, kind in _callees(rhs):
                if kind == "call":
                    internal.add(callee)
    internal.discard(entry)
    return internal


_SKIP_BYTES_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
                   "bitcast(", "while(", "after-all(", "copy-done(",
                   "all-gather-done(", "all-reduce-done(",
                   "collective-permute-done(")


def _instr_bytes(comp, comps, rhs):
    """HBM traffic of one materialized instruction."""
    if any(op in rhs for op in _SKIP_BYTES_OPS):
        return 0
    args = rhs.split("(", 1)[1].split(")", 1)[0] if "(" in rhs else ""
    opnds = _OPND.findall(args)
    if " dynamic-update-slice(" in rhs and len(opnds) >= 2:
        # in-place DUS: traffic = update slice read + write
        return 2 * _shape_bytes(comp.shapes.get(opnds[1], ""))
    res_b = _shape_bytes(rhs.split("(", 1)[0])
    # fusions: use slice-aware per-parameter reads from the fused body
    callee = None
    i = rhs.find("calls=")
    if " fusion(" in rhs and i >= 0:
        m = _OPND.match(rhs[i + len("calls="):])
        if m:
            callee = comps.get(m.group(1))
    if callee is not None:
        reads = callee.param_read_bytes()
        opnd_b = 0
        for idx, op in enumerate(opnds):
            full = _shape_bytes(comp.shapes.get(op, ""))
            opnd_b += min(reads.get(idx, full), full) if full else full
        dus = callee.dus_root_bytes()
        if dus is not None:
            res_b = dus                      # in-place: write only the slice
        return res_b + opnd_b
    return res_b + sum(_shape_bytes(comp.shapes.get(op, "")) for op in opnds)


def analyze(text):
    comps, entry = parse_computations(text)
    mult = compute_multipliers(comps, entry)
    internal = _is_fusion_internal(comps, entry)

    flops = 0.0
    bytes_hbm = 0.0
    coll = defaultdict(float)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for iname, rhs in comp.instrs:
            # --- dot flops (counted everywhere, incl. fusion internals) ----
            if " dot(" in rhs or rhs.startswith("dot("):
                res_dt, res_dims = _first_shape(rhs.split("(", 1)[0])
                cm = _CONTRACT.search(rhs)
                contract = 1
                if cm:
                    opnds = _OPND.findall(rhs.split("(", 1)[1].split(")", 1)[0])
                    if opnds:
                        lhs_head = comp.shapes.get(opnds[0], "")
                        _, lhs_dims = _first_shape(lhs_head)
                        if lhs_dims:
                            for ci in cm.group(1).split(","):
                                if ci:
                                    contract *= lhs_dims[int(ci)]
                if res_dims is not None:
                    n = 1
                    for d in res_dims:
                        n *= d
                    flops += m * 2.0 * n * contract
            # --- collectives ----------------------------------------------
            for ck in _COLLS:
                if f" {ck}(" in rhs or f" {ck}-start(" in rhs:
                    b = _shape_bytes(rhs.split("(", 1)[0])
                    if ck == "all-reduce":
                        b *= 2
                    if ck == "all-gather":
                        pass        # result already = gathered size
                    coll[ck] += m * b
                    break
            # --- HBM traffic (materialized computations only) --------------
            if cname not in internal:
                bytes_hbm += m * _instr_bytes(comp, comps, rhs)

    coll_total = sum(coll.values())
    return {
        "flops": flops,
        "bytes": bytes_hbm,
        "collectives": dict(coll, total=coll_total),
        "computations": len(comps),
    }


_META = re.compile(r'op_name="([^"]*)"')


def top_dots(text, k=20):
    """The k largest dot contributors (flops x trip multiplier) with their
    jax-level op_name metadata — the profiler for §Perf iterations."""
    comps, entry = parse_computations(text)
    mult = compute_multipliers(comps, entry)
    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for iname, rhs in comp.instrs:
            if " dot(" not in rhs and not rhs.startswith("dot("):
                continue
            res_dt, res_dims = _first_shape(rhs.split("(", 1)[0])
            cm = _CONTRACT.search(rhs)
            contract = 1
            opnds = _OPND.findall(rhs.split("(", 1)[1].split(")", 1)[0])
            lhs_dims = None
            if cm and opnds:
                _, lhs_dims = _first_shape(comp.shapes.get(opnds[0], ""))
                if lhs_dims:
                    for ci in cm.group(1).split(","):
                        if ci:
                            contract *= lhs_dims[int(ci)]
            if res_dims is None:
                continue
            n = 1
            for d in res_dims:
                n *= d
            meta = _META.search(rhs)
            rows.append({
                "flops": m * 2.0 * n * contract, "mult": m,
                "result": f"{res_dt}{res_dims}", "lhs": str(lhs_dims),
                "contract": contract, "comp": cname,
                "op_name": meta.group(1) if meta else "?",
            })
    rows.sort(key=lambda r: -r["flops"])
    return rows[:k]


def top_collectives(text, k=20):
    """The k largest collective ops (bytes x trips) with metadata."""
    comps, entry = parse_computations(text)
    mult = compute_multipliers(comps, entry)
    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for iname, rhs in comp.instrs:
            for ck in _COLLS:
                if f" {ck}(" in rhs or f" {ck}-start(" in rhs:
                    b = _shape_bytes(rhs.split("(", 1)[0])
                    if ck == "all-reduce":
                        b *= 2
                    meta = _META.search(rhs)
                    rows.append({
                        "bytes": m * b, "mult": m, "kind": ck,
                        "shape": rhs.split("(", 1)[0].strip(),
                        "op_name": (meta.group(1) if meta else "?")[-110:]})
                    break
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]


def top_bytes(text, k=20):
    """The k largest HBM-traffic instructions (materialized comps only)."""
    comps, entry = parse_computations(text)
    mult = compute_multipliers(comps, entry)
    internal = _is_fusion_internal(comps, entry)
    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0 or cname in internal:
            continue
        for iname, rhs in comp.instrs:
            b = _instr_bytes(comp, comps, rhs)
            if not b:
                continue
            meta = _META.search(rhs)
            rows.append({"bytes": m * b, "mult": m, "instr": iname,
                         "comp": cname,
                         "op_name": (meta.group(1) if meta else "?")[:120]})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]
