"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch bigbird-base --smoke \
        --steps 200 --batch 8 --seq 512 --ckpt-dir /tmp/ckpt

Full-scale flags target the production mesh (the dry-run proves those
compile); on this CPU container use --smoke for the reduced same-family
config.  Integrates: deterministic sharded data, per-arch optimizer recipe
(adamw/adafactor, cosine/WSD), checkpoint/restart (restores the latest step
automatically), and elastic replan on simulated failure (--fail-at).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import checkpoint as CKPT
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M
from repro.obs import metrics as Om


def _metrics_tick(step, metrics, tokens_total, dt_step, tokens_per_sec,
                  prefix="train"):
    """Record one --metrics-interval tick into obs.metrics and print the
    registry as one machine-readable JSONL line (loss/lr/grad_norm as
    gauges, cumulative tokens, per-step wall-clock histogram)."""
    for k in ("loss", "lr", "grad_norm", "agree"):
        if k in metrics:
            Om.gauge(f"{prefix}_{k}",
                     f"Latest {prefix} {k}").set(float(metrics[k]))
    tok = Om.counter(f"{prefix}_tokens_total",
                     f"Cumulative tokens consumed by {prefix}")
    tok.inc(max(0.0, tokens_total - tok.value()))
    if dt_step > 0:
        Om.histogram(f"{prefix}_step_seconds",
                     f"{prefix} step wall-clock").observe(dt_step)
        Om.gauge(f"{prefix}_tokens_per_sec",
                 f"{prefix} token throughput").set(tokens_per_sec)
    print("[metrics] " + Om.jsonl_line({"step": step}), flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bigbird-base")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-interval", type=int, default=0, metavar="N",
                    help="every N steps, record loss/lr/grad_norm/"
                         "throughput into obs.metrics and print the "
                         "registry as one machine-readable JSONL line "
                         "(0 = off)")
    ap.add_argument("--impl", default="pallas",
                    choices=["pallas", "blockified", "reference"],
                    help="sparse-attention implementation (pallas = fused "
                         "kernels with custom_vjp backward, the default)")
    ap.add_argument("--pattern", default="bigbird",
                    choices=["bigbird", "importance", "littlebird"],
                    help="attention-pattern policy for bigbird layers "
                         "(core/patterns.py; importance = Smart Bird-style "
                         "scored selection, littlebird = sliding window + "
                         "packed globals)")
    ap.add_argument("--mlm", action="store_true", default=None)
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback gradient sync over a pod "
                         "axis spanning all local devices "
                         "(optim/compression.py)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a failure at this step (FT test)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distill", action="store_true",
                    help="distillation mode: train --arch (the student, "
                         "e.g. bigbird-draft) against --teacher-arch "
                         "teacher logits with per-position KL on "
                         "teacher-forced CLM positions (serve/spec.py "
                         "draft providers load the resulting checkpoint)")
    ap.add_argument("--teacher-arch", default="bigbird-base")
    ap.add_argument("--teacher-ckpt", default=None,
                    help="checkpoint dir for teacher params (--distill); "
                         "default: deterministic random init from "
                         "--teacher-seed")
    ap.add_argument("--teacher-seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.distill:
        return distill_main(args)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.seq:
        cfg = dataclasses.replace(cfg, max_seq=max(cfg.max_seq, args.seq))
    from repro.configs.common import with_attn_impl, with_attn_pattern
    cfg = with_attn_impl(cfg, args.impl)
    if args.pattern != "bigbird":
        cfg = with_attn_pattern(cfg, args.pattern)
    mlm = args.mlm if args.mlm is not None else (args.arch == "bigbird-base")

    opt = S.make_optimizer(kind=configs.optimizer_for(args.arch),
                           schedule=configs.schedule_for(args.arch),
                           peak_lr=args.lr, warmup=args.warmup,
                           total=args.steps)
    grad_sync = None
    if args.grad_compress:
        from jax.sharding import Mesh, PartitionSpec
        from repro.optim import compression as Comp
        pod_mesh = Mesh(np.array(jax.devices()), ("pod",))

        def grad_sync(grads, err):
            ps = jax.tree.map(lambda _: PartitionSpec(), grads)
            return Comp.compressed_grad_sync(grads, err, pod_mesh, ps,
                                             axis="pod")
    train_step = jax.jit(S.make_train_step(cfg, opt,
                                           microbatches=args.microbatches,
                                           grad_sync=grad_sync),
                         donate_argnums=(0,))

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        seed=args.seed, mlm=mlm))

    start_step = 0
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        state, start_step = CKPT.restore(args.ckpt_dir)
        state = jax.tree.map(jnp.asarray, state)
        print(f"[train] restored checkpoint at step {start_step}")
    else:
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
    if args.grad_compress and "grad_err" not in state:
        from repro.optim import compression as Comp
        state["grad_err"] = Comp.init_error_state(state["params"])

    nparams = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={args.arch} params={nparams/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq} mlm={mlm} impl={args.impl}")

    pending = None
    t0 = time.time()
    for step in range(start_step, args.steps):
        if args.fail_at is not None and step == args.fail_at:
            if pending is not None:
                pending.join()       # in-flight checkpoint commits first
            raise RuntimeError(f"simulated node failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.kind == "encdec":
            B = args.batch
            batch = {"frames": jax.random.normal(
                         jax.random.PRNGKey(step), (B, args.seq, cfg.d_model)),
                     "tokens": batch["tokens"][:, :cfg.dec_len],
                     "labels": batch["labels"][:, :cfg.dec_len]}
        if cfg.frontend == "patch":
            batch["frontend_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.frontend_len,
                                           cfg.d_model), cfg.dtype)
        state, metrics = train_step(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(step - start_step + 1, 1)
            print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} {dt:.2f}s/step",
                  flush=True)
        if args.metrics_interval and (step % args.metrics_interval == 0
                                      or step == args.steps - 1):
            done = step - start_step + 1
            dt = (time.time() - t0) / max(done, 1)
            _metrics_tick(step, metrics, done * args.batch * args.seq, dt,
                          args.batch * args.seq / max(dt, 1e-9))
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = CKPT.save_async(state, args.ckpt_dir, step + 1)
    if pending is not None:
        pending.join()
    if args.ckpt_dir:
        CKPT.save(state, args.ckpt_dir, args.steps)
        print(f"[train] final checkpoint at step {args.steps}")
    return state


def distill_main(args):
    """--distill: train the student (--arch, typically bigbird-draft)
    against frozen --teacher-arch logits with per-position KL on
    teacher-forced CLM positions.  The checkpoint it writes is what
    serve/spec.py draft providers (ModelDraft / TreeDraft) load."""
    from repro.configs.common import with_attn_impl

    mk = configs.smoke if args.smoke else configs.get
    scfg, tcfg = mk(args.arch), mk(args.teacher_arch)
    if args.seq:
        scfg = dataclasses.replace(scfg, max_seq=max(scfg.max_seq, args.seq))
        tcfg = dataclasses.replace(tcfg, max_seq=max(tcfg.max_seq, args.seq))
    scfg = with_attn_impl(scfg, args.impl)
    tcfg = with_attn_impl(tcfg, args.impl)
    assert scfg.kind == tcfg.kind == "lm", "distill is decoder-LM only"

    if args.teacher_ckpt and CKPT.latest_step(args.teacher_ckpt) is not None:
        tstate, tstep = CKPT.restore(args.teacher_ckpt)
        teacher_params = jax.tree.map(jnp.asarray, tstate["params"])
        print(f"[distill] teacher {args.teacher_arch} from "
              f"{args.teacher_ckpt} step {tstep}")
    else:
        teacher_params = M.init(tcfg, jax.random.PRNGKey(args.teacher_seed))
        print(f"[distill] teacher {args.teacher_arch} "
              f"random-init seed={args.teacher_seed}")

    opt = S.make_optimizer(kind=configs.optimizer_for(args.arch),
                           schedule=configs.schedule_for(args.arch),
                           peak_lr=args.lr, warmup=args.warmup,
                           total=args.steps)
    distill_step = jax.jit(S.make_distill_step(scfg, tcfg, opt),
                           donate_argnums=(0,))

    # teacher-forced CLM stream: same deterministic generator the serving
    # bench replays, never MLM (drafts serve a causal decode loop)
    data = SyntheticLM(DataConfig(
        vocab_size=scfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        seed=args.seed, mlm=False))

    start_step = 0
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        state, start_step = CKPT.restore(args.ckpt_dir)
        state = jax.tree.map(jnp.asarray, state)
        print(f"[distill] restored student checkpoint at step {start_step}")
    else:
        params = M.init(scfg, jax.random.PRNGKey(args.seed))
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}

    nparams = sum(int(np.prod(x.shape))
                  for x in jax.tree.leaves(state["params"]))
    ntp = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(teacher_params))
    print(f"[distill] student={args.arch} ({nparams/1e6:.2f}M) "
          f"teacher={args.teacher_arch} ({ntp/1e6:.2f}M) "
          f"batch={args.batch} seq={args.seq} impl={args.impl}")

    agree = 0.0
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = distill_step(state, teacher_params, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(step - start_step + 1, 1)
            agree = float(metrics["agree"])
            print(f"[distill] step={step} kl={float(metrics['loss']):.4f} "
                  f"agree={agree:.3f} lr={float(metrics['lr']):.2e} "
                  f"{dt:.2f}s/step", flush=True)
        if getattr(args, "metrics_interval", 0) and (
                step % args.metrics_interval == 0 or step == args.steps - 1):
            done = step - start_step + 1
            dt = (time.time() - t0) / max(done, 1)
            _metrics_tick(step, metrics, done * args.batch * args.seq, dt,
                          args.batch * args.seq / max(dt, 1e-9),
                          prefix="distill")
    if args.ckpt_dir:
        CKPT.save(state, args.ckpt_dir, args.steps)
        print(f"[distill] final checkpoint at step {args.steps}")
    return state


if __name__ == "__main__":
    main()
