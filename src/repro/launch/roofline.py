"""Roofline report generator + per-cell HLO profiler.

  PYTHONPATH=src python -m repro.launch.roofline --table
      -> markdown roofline table from experiments/dryrun_*.json

  PYTHONPATH=src python -m repro.launch.roofline --profile yi-6b:train_4k
      -> compile that cell (512 fake devices) and print the top dot / byte /
         collective contributors with trip multipliers — the profile used by
         the §Perf hypothesis loop.
"""
import os
if "--profile" in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
from pathlib import Path

EXP = Path(__file__).resolve().parents[3] / "experiments"


def fmt_table(mesh="single"):
    data = json.loads((EXP / f"dryrun_{mesh}.json").read_text())
    lines = [
        "| arch:shape | mode | peak GiB/chip | compute s | memory s | "
        "collective s | dominant | ideal s | frac-of-roofline | MF/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(data):
        rec = data[key]
        if not rec.get("ok"):
            lines.append(f"| {key} | — | FAILED: {rec['error'][:60]} |")
            continue
        r = rec["roofline"]
        frac = r.get("fraction_of_roofline")
        mf = rec.get("model_vs_hlo_flops")
        lines.append(
            f"| {key} | {rec['mode']} | "
            f"{rec['bytes_per_device']['peak']/2**30:.2f} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant'][:-2]} | "
            f"{r.get('ideal_compute_s', 0):.3f} | "
            f"{'' if frac is None else f'{frac:.3f}'} | "
            f"{'' if mf is None else f'{mf:.2f}'} |")
    return "\n".join(lines)


def profile(cell, mesh_kind="single", microbatches=8):
    import jax
    from repro.launch import hlo_cost, steps
    from repro.launch.mesh import make_production_mesh

    arch, shape = cell.split(":")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    built = steps.build_step(arch, shape, mesh, microbatches=microbatches)
    jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                 out_shardings=built["out_shardings"],
                 donate_argnums=built["donate"])
    compiled = jf.lower(*built["abstract_args"]).compile()
    txt = compiled.as_text()
    parsed = hlo_cost.analyze(txt)
    print(f"== {cell} on {mesh_kind} ==")
    print(f"flops/dev {parsed['flops']:.3e}  bytes/dev {parsed['bytes']:.3e}")
    print("collectives:", {k: f"{v:.2e}" for k, v in parsed['collectives'].items()})
    print("\n-- top dots (flops x trips) --")
    for r in hlo_cost.top_dots(txt, 12):
        print(f"  {r['flops']:.2e} x{r['mult']:6.0f} {r['result']:30s} "
              f"K={r['contract']:<7d} {r['op_name'][-75:]}")
    print("\n-- top HBM traffic --")
    for r in hlo_cost.top_bytes(txt, 12):
        print(f"  {r['bytes']:.2e} x{r['mult']:6.0f} {r['op_name'][-85:]}")
    return txt, parsed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--profile", default=None)
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args()
    if args.profile:
        profile(args.profile, args.mesh, args.microbatches)
    if args.table or not args.profile:
        print(fmt_table(args.mesh))


if __name__ == "__main__":
    main()
