"""Serving launcher on the generation Engine (repro/serve/).

    PYTHONPATH=src python -m repro.launch.serve --arch bigbird-base --smoke \
        --prompt-len 128 --gen 32 --batch 4 --temperature 0.8 --top-p 0.95

Demonstrates the bounded BigBird-decode path: for sparse-attention archs the
per-token cache read is O((g+w+r)*b) regardless of context length.  The
whole decode loop runs inside one jitted `lax.while_loop` — no per-token
Python dispatch (Engine.generate).

`--mesh DxM` (e.g. `--mesh 2x2`) serves through the mesh-parallel
continuous-batching path instead: slots and KV pages shard over the data
axis, kv heads over the model axis, and every request's token stream is
bit-identical to the replicated run (DESIGN.md §Mesh-parallel serving).
Needs D*M visible devices (CPU: XLA_FLAGS=--xla_force_host_platform_device_count=8).

`--spec K` (e.g. `--spec 4`) serves through the speculative-decoding
path: a draft provider (`--spec-provider ngram|draft|tree` — prompt
n-grams, a small bigbird-draft model, or the same model drafting a token
TREE with per-depth fanout `--spec-fanout`) proposes up to K tokens per
slot per step and one verify forward scores them all — losslessly, so
the streams match the vanilla engine's exactly (DESIGN.md §Speculative
decoding).  The end-of-run summary prints the accepted-length histogram
and, for trees, the off-spine acceptance stats.

`--stream` serves through the asyncio front-end (AsyncEngine): requests
are submitted with staggered arrivals and every token is printed the
moment it crosses the device boundary, interleaved across requests.  The
streams are bit-identical to what the synchronous drain would produce
(DESIGN.md §Async front-end); `--stagger` controls the arrival gap.

`--kv-dtype int8` stores KV pages quantized with per-page scales (~4x
less KV HBM, lossy — DESIGN.md §Paged cache), and `--host-swap` lets the
engine swap cold residents' pages to host memory instead of queuing on
page exhaustion (exact; unsharded engines only).  Both compose with the
other demo paths (`--host-swap` excludes `--mesh`).
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.obs import trace as Otr
from repro.serve import AsyncEngine, Engine, Request, SamplingSpec, SpecConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bigbird-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve over a (data, model) mesh, e.g. 2x2")
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="speculative decoding with K draft tokens/round")
    ap.add_argument("--spec-provider", default="ngram",
                    choices=("ngram", "draft", "tree"),
                    help="draft source: prompt-lookup n-grams, a small "
                         "bigbird-draft model (linear), or the same model "
                         "drafting a token TREE (per-depth fanout, one "
                         "verify forward scores every branch)")
    ap.add_argument("--spec-fanout", default=None, metavar="F1,F2,..",
                    help="tree branching per depth (--spec-provider tree); "
                         "default 2 per depth over K levels")
    ap.add_argument("--stream", action="store_true",
                    help="serve through the async front-end and print "
                         "tokens as they arrive")
    ap.add_argument("--stagger", type=float, default=0.05, metavar="S",
                    help="arrival gap between streamed requests (seconds)")
    ap.add_argument("--kv-dtype", default=None, choices=(None, "int8"),
                    help="quantized KV page stores with per-page scales "
                         "(default: the model dtype, exact)")
    ap.add_argument("--host-swap", action="store_true",
                    help="swap cold residents' KV pages to host memory "
                         "under page pressure instead of queuing "
                         "(unsharded engines only)")
    ap.add_argument("--pattern", default="bigbird",
                    choices=["bigbird", "importance", "littlebird"],
                    help="attention-pattern policy for bigbird layers "
                         "(core/patterns.py; same engine, paged pool and "
                         "kernels — only the block layout changes)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve live Prometheus metrics on this port while "
                         "the demo runs (0 picks an ephemeral port; routes: "
                         "/metrics, /metrics.json, /healthz)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record per-request timelines + engine-step phase "
                         "spans and write Chrome trace-event JSON here "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--jax-profile", default=None, metavar="LOGDIR",
                    help="bracket the run with jax.profiler.trace(LOGDIR) "
                         "for device-side correlation (no-op if the "
                         "profiler is unavailable)")
    args = ap.parse_args(argv)
    assert sum(map(bool, (args.mesh, args.spec, args.stream))) <= 1, \
        "--mesh, --spec and --stream are separate demo paths; pick one"
    assert not (args.host_swap and args.mesh), \
        "--host-swap requires an unsharded engine (no --mesh)"
    mserver = None
    if args.metrics_port is not None:
        from repro.obs import server as Osrv
        mserver = Osrv.start_metrics_server(args.metrics_port)
        print(f"[serve] metrics: http://127.0.0.1:{mserver.port}/metrics",
              flush=True)
    if args.trace:
        Otr.enable()
    try:
        with Otr.profiler_window(args.jax_profile):
            return _serve(args)
    finally:
        if args.trace:
            n = Otr.dump(args.trace)
            print(f"[serve] trace: wrote {n} events to {args.trace}")
        if mserver is not None:
            mserver.shutdown()


def _serve(args):
    """Run the demo path `main`'s flags selected (factored out so main
    can bracket it with the metrics server / trace dump / profiler)."""
    eng_kw = {}
    if args.kv_dtype:
        eng_kw["kv_dtype"] = args.kv_dtype
    if args.host_swap:
        eng_kw["host_swap"] = True

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.pattern != "bigbird":
        from repro.configs.common import with_attn_pattern
        cfg = with_attn_pattern(cfg, args.pattern)
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    max_len = args.prompt_len + args.gen

    B = args.batch
    gen = args.gen
    prompt = jax.random.randint(key, (B, args.prompt_len), 4, cfg.vocab_size)
    frames = frontend = None
    if cfg.kind == "encdec":
        frames = jax.random.normal(key, (B, args.prompt_len, cfg.d_model))
        # decoder budget is dec_len: prompt + gen - 1 positions must fit
        gen = min(gen, cfg.dec_len)
        prompt = prompt[:, :max(1, min(args.prompt_len,
                                       cfg.dec_len - gen + 1))]
        max_len = 0                     # engine defaults to cfg.dec_len
    if cfg.frontend == "patch":
        frontend = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), cfg.dtype)
        max_len = max(max_len, cfg.frontend_len + gen)

    sampling = SamplingSpec(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed)

    if args.mesh or args.spec or args.stream:
        # these demo paths serve through paged continuous batching
        # (submit/step/drain), which requires a causal attention-only LM;
        # encoder-style (MLM) bigbird configs are served with their
        # pattern flipped causal, the standard decoder-only arrangement.
        if (cfg.kind == "lm" and cfg.attn.kind in ("bigbird", "window")
                and not cfg.attn.causal
                and all(ls.kind == "attn" and ls.attn is None
                        for ls in cfg.layer_pattern)):
            # causality changes no param shape: the existing weights serve
            cfg = dataclasses.replace(
                cfg, attn=dataclasses.replace(cfg.attn, causal=True))
            print(f"[serve] continuous serving: flipped {args.arch} causal")

    if args.stream:
        # interactive async streaming: tokens print as they arrive, with a
        # 2-deep dispatch pipeline keeping the device busy between polls
        engine = Engine(cfg, params, max_len=max_len, capacity=B,
                        dispatch_depth=2, **eng_kw)
        t0 = time.time()

        async def consume(i, sess):
            first = None
            async for tok in sess:
                now = time.time() - t0
                first = first if first is not None else now
                print(f"[stream] t={now:6.2f}s req{i} -> {tok}", flush=True)
            r = await sess.result()
            print(f"[stream] t={time.time()-t0:6.2f}s req{i} done "
                  f"({r.finish_reason}, {len(r.tokens)} tokens, "
                  f"ttft {first:.2f}s)", flush=True)
            return r

        async def run():
            front = AsyncEngine(engine)
            tasks = []
            for i in range(B):
                sess = await front.submit(
                    np.asarray(prompt[i]), gen,
                    sampling=dataclasses.replace(sampling, seed=i))
                tasks.append(asyncio.ensure_future(consume(i, sess)))
                await asyncio.sleep(args.stagger)
            results = await asyncio.gather(*tasks)
            await front.close()
            return results

        results = asyncio.run(run())
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in results)
        print(f"[serve] arch={cfg.name} streamed {toks} tokens from {B} "
              f"requests in {dt:.2f}s ({toks/dt:.1f} tok/s), mean TTFT "
              f"{np.mean([r.ttft_s for r in results]):.2f}s")
        return jnp.asarray([r.tokens for r in results])

    if args.spec:
        # speculative decoding: draft/verify with lossless acceptance
        spec = SpecConfig(k=args.spec, provider="ngram")
        if args.spec_provider in ("draft", "tree"):
            dcfg = (configs.smoke("bigbird-draft") if args.smoke
                    else configs.get("bigbird-draft"))
            dcfg = dataclasses.replace(dcfg, vocab_size=cfg.vocab_size)
            dparams = M.init(dcfg, jax.random.PRNGKey(args.seed + 1))
            if args.spec_provider == "tree":
                fanout = (tuple(int(f) for f in args.spec_fanout.split(","))
                          if args.spec_fanout else ())
                spec = SpecConfig(k=args.spec, provider="tree",
                                  draft_cfg=dcfg, draft_params=dparams,
                                  fanout=fanout,
                                  draft_temperature=args.temperature,
                                  draft_top_k=args.top_k,
                                  draft_top_p=args.top_p)
            else:
                spec = SpecConfig(k=args.spec, provider="model",
                                  draft_cfg=dcfg, draft_params=dparams)
        engine = Engine(cfg, params, max_len=max_len, capacity=B, spec=spec,
                        **eng_kw)
        for i in range(B):
            engine.submit(Request(prompt=np.asarray(prompt[i]),
                                  max_new_tokens=gen, sampling=sampling))
        t0 = time.time()
        results = engine.drain()
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in results)
        st = engine.spec_stats()
        with_drafts = [r.acceptance_rate for r in results if r.draft_proposed]
        acc = np.mean(with_drafts) if with_drafts else 0.0
        print(f"[serve] arch={cfg.name} spec k={args.spec} "
              f"provider={args.spec_provider}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s), mean accepted/round "
              f"{st['mean_accepted_len']:.2f}, acceptance {acc:.0%}, "
              f"mean TPOT {np.mean([r.tpot_s for r in results])*1e3:.1f}ms")
        # accepted-length histogram: hist[m] = verify rounds that kept m
        # draft tokens (the per-round acceptance distribution, not just
        # the mean — regressions usually show up in the tail first)
        hist = st["accept_len_hist"]
        print("[serve] accept_len_hist "
              + " ".join(f"{m}:{int(n)}" for m, n in enumerate(hist)))
        if "offspine_hist" in st:
            # tree stats: rounds whose accepted path left the greedy spine
            # at depth m — the branches' contribution over a linear draft
            print(f"[serve] tree fanout={st['fanout']} "
                  f"nodes={st['tree_nodes']} "
                  f"offspine_accepted={int(st['offspine_accepted'])} "
                  f"offspine_hist "
                  + " ".join(f"{m}:{int(n)}"
                             for m, n in enumerate(st["offspine_hist"])))
        print("[serve] sample:", results[0].tokens[:16])
        return jnp.asarray([r.tokens for r in results])

    if args.mesh:
        from repro.serve import mesh as Mx
        mesh = Mx.parse_mesh(args.mesh)
        engine = Engine(cfg, params, max_len=max_len, capacity=B, mesh=mesh,
                        **eng_kw)
        st = engine.stats()
        print(f"[serve] mesh {args.mesh}: {st.data_shards} data shard(s) x "
              f"{st.pages_per_shard} pages, "
              f"{st.kv_bytes_per_shard / 2**20:.1f} MiB KV per shard")
        for i in range(B):
            engine.submit(Request(prompt=np.asarray(prompt[i]),
                                  max_new_tokens=gen, sampling=sampling))
        t0 = time.time()
        results = engine.drain()
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in results)
        print(f"[serve] arch={cfg.name} mesh={args.mesh} generated {toks} "
              f"tokens in {dt:.2f}s ({toks/dt:.1f} tok/s aggregate)")
        print("[serve] sample:", results[0].tokens[:16])
        return jnp.asarray([r.tokens for r in results])

    engine = Engine(cfg, params, max_len=max_len, capacity=B, **eng_kw)

    t0 = time.time()
    out = engine.generate([jnp.asarray(p) for p in prompt], gen,
                          sampling=sampling, frames=frames,
                          frontend_embeds=frontend)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} generated {B}x{gen} tokens "
          f"in {dt:.2f}s ({B*gen/dt:.1f} tok/s)")
    print("[serve] sample:", out.tokens[0, :16].tolist())
    return jnp.asarray(out.tokens)


if __name__ == "__main__":
    main()
