"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch bigbird-base --smoke \
        --prompt-len 128 --gen 32 --batch 4

Demonstrates the bounded BigBird-decode path: for sparse-attention archs the
per-token cache read is O((g+w+r)*b) regardless of context length.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import steps as S
from repro.models import decode as Dec
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bigbird-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    max_len = args.prompt_len + args.gen

    B = args.batch
    prompt = jax.random.randint(key, (B, args.prompt_len), 4, cfg.vocab_size)
    batch = {"tokens": prompt, "labels": prompt}
    if cfg.kind == "encdec":
        batch["frames"] = jax.random.normal(key, (B, args.prompt_len, cfg.d_model))
        batch["tokens"] = prompt[:, :min(args.prompt_len, cfg.dec_len)]
    if cfg.frontend == "patch":
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), cfg.dtype)

    prefill = jax.jit(lambda p, b: Dec.prefill(p, cfg, b, max_len))
    step = jax.jit(lambda p, c, t, i: Dec.decode_step(p, cfg, c, t, i))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    dec_start = (batch["tokens"].shape[1] if cfg.kind == "encdec"
                 else args.prompt_len)
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, tok, dec_start + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} generated {B}x{args.gen} tokens "
          f"in {dt:.2f}s ({B*args.gen/dt:.1f} tok/s)")
    print("[serve] sample:", toks[0, :16].tolist())
    return toks


if __name__ == "__main__":
    main()
