"""Serving launcher on the generation Engine (repro/serve/).

    PYTHONPATH=src python -m repro.launch.serve --arch bigbird-base --smoke \
        --prompt-len 128 --gen 32 --batch 4 --temperature 0.8 --top-p 0.95

Demonstrates the bounded BigBird-decode path: for sparse-attention archs the
per-token cache read is O((g+w+r)*b) regardless of context length.  The
whole decode loop runs inside one jitted `lax.while_loop` — no per-token
Python dispatch (Engine.generate).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.serve import Engine, SamplingSpec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bigbird-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    max_len = args.prompt_len + args.gen

    B = args.batch
    gen = args.gen
    prompt = jax.random.randint(key, (B, args.prompt_len), 4, cfg.vocab_size)
    frames = frontend = None
    if cfg.kind == "encdec":
        frames = jax.random.normal(key, (B, args.prompt_len, cfg.d_model))
        # decoder budget is dec_len: prompt + gen - 1 positions must fit
        gen = min(gen, cfg.dec_len)
        prompt = prompt[:, :max(1, min(args.prompt_len,
                                       cfg.dec_len - gen + 1))]
        max_len = 0                     # engine defaults to cfg.dec_len
    if cfg.frontend == "patch":
        frontend = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), cfg.dtype)
        max_len = max(max_len, cfg.frontend_len + gen)

    engine = Engine(cfg, params, max_len=max_len, capacity=B)
    sampling = SamplingSpec(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed)

    t0 = time.time()
    out = engine.generate([jnp.asarray(p) for p in prompt], gen,
                          sampling=sampling, frames=frames,
                          frontend_embeds=frontend)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} generated {B}x{gen} tokens "
          f"in {dt:.2f}s ({B*gen/dt:.1f} tok/s)")
    print("[serve] sample:", out.tokens[0, :16].tolist())
    return jnp.asarray(out.tokens)


if __name__ == "__main__":
    main()
