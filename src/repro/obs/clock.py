"""Injectable wall-clock for the serving/training stack.

Every latency-bearing timestamp in the serving path (engine submit/TTFT/
finish, frontend deadlines, step spans, trace events) reads
`obs.clock()` instead of calling `time.perf_counter()` directly, so
timing-sensitive tests can install a deterministic `FakeClock` and
assert exact TTFT/TPOT/queue-wait values instead of sleeping real time.

The default clock IS `time.perf_counter` — monotonic seconds with an
arbitrary epoch — and swapping it never touches device code: the clock
is only ever read on the host, outside jitted regions.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

_clock: Callable[[], float] = time.perf_counter


def clock() -> float:
    """Current time in (monotonic) seconds from the installed clock."""
    return _clock()


def set_clock(fn: Optional[Callable[[], float]]) -> None:
    """Install `fn` as the process clock; None restores perf_counter."""
    global _clock
    _clock = time.perf_counter if fn is None else fn


def get_clock() -> Callable[[], float]:
    """The currently installed clock callable (for save/restore)."""
    return _clock


class FakeClock:
    """A deterministic manually-advanced clock for tests.

        fake = FakeClock(start=100.0)
        obs.set_clock(fake)
        fake.advance(0.25)       # every obs.clock() read now returns 100.25
    """

    def __init__(self, start: float = 0.0):
        """Start the clock at `start` seconds."""
        self.t = float(start)

    def __call__(self) -> float:
        """Read the clock (the `obs.clock()` protocol)."""
        return self.t

    def advance(self, dt: float) -> float:
        """Move the clock forward `dt` seconds; returns the new time."""
        self.t += float(dt)
        return self.t
