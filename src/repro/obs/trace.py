"""Per-request event timelines + engine-step phase spans, Chrome-trace
exportable.

A `TraceRecorder` is a bounded ring buffer of timestamped events:

  * per-request timeline events (tid = request id + 1): submit ->
    queue_wait span -> admit -> each prefill chunk -> first_token ->
    spec verify rounds -> swap_out / swap_in -> a closing `request`
    span covering submit..finish, each carrying args (page counts,
    accepted lengths, finish reason);
  * engine-step phase spans (tid = 0): admission / prefill / decode /
    spec_round / swap, plus the whole `engine_step` envelope.

Recording is OFF by default (`enable()` / `serve.py --trace` /
`benchmarks/serving.py --trace` turn it on) and costs one deque append
per event when on — events are recorded on the host, strictly outside
jitted regions, with timestamps from the injectable `obs.clock()`.  The
ring (`capacity` events) evicts oldest-first, so a long run keeps its
tail.

Export (`to_chrome()` / `dump(path)` / `Engine.dump_trace(path)`) emits
Chrome trace-event JSON — `{"traceEvents": [...]}` with "X"
(complete-span) and "i" (instant) phases, microsecond timestamps, and
thread-name metadata — loadable in Perfetto / chrome://tracing.

`profiler_window(logdir)` is the optional device-side correlation hook:
a context manager wrapping `jax.profiler.trace` when available (and a
no-op otherwise), so a host-side trace window can be captured together
with the device profile it brackets.
"""
from __future__ import annotations

import collections
import contextlib
import json
from typing import Dict, List, Optional

from repro.obs.clock import clock

DEFAULT_CAPACITY = 65536


class TraceRecorder:
    """A bounded ring of trace events (see the module docstring)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        """Create a disabled recorder holding at most `capacity` events."""
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._tid_names: Dict[int, str] = {}
        self.enabled = False

    def enable(self, capacity: Optional[int] = None) -> None:
        """Start recording; `capacity` resizes (and clears) the ring."""
        if capacity is not None and capacity != self._ring.maxlen:
            self._ring = collections.deque(maxlen=capacity)
        self.enabled = True

    def disable(self) -> None:
        """Stop recording (already-recorded events stay exportable)."""
        self.enabled = False

    def clear(self) -> None:
        """Drop every recorded event and thread name."""
        self._ring.clear()
        self._tid_names.clear()

    def __len__(self) -> int:
        """Number of events currently held."""
        return len(self._ring)

    def name_thread(self, tid: int, name: str) -> None:
        """Label `tid` in the exported trace (e.g. "req 3", "engine")."""
        if self.enabled:
            self._tid_names[tid] = name

    def instant(self, name: str, tid: int = 0, ts: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        """Record a point event at `ts` (default: now) on thread `tid`."""
        if self.enabled:
            self._ring.append(
                ("i", name, tid, clock() if ts is None else ts, 0.0, args))

    def span(self, name: str, t0: float, t1: Optional[float] = None,
             tid: int = 0, args: Optional[dict] = None) -> None:
        """Record a complete span [t0, t1] (t1 default: now) on `tid`."""
        if self.enabled:
            if t1 is None:
                t1 = clock()
            self._ring.append(("X", name, tid, t0, max(0.0, t1 - t0), args))

    def events(self) -> List[dict]:
        """The recorded events, oldest first, as plain dicts."""
        return [{"ph": ph, "name": name, "tid": tid, "ts": ts,
                 "dur": dur, "args": args or {}}
                for ph, name, tid, ts, dur, args in self._ring]

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (perfetto-loadable)."""
        events: List[dict] = []
        for tid, name in sorted(self._tid_names.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": name}})
        for ph, name, tid, ts, dur, args in self._ring:
            ev = {"ph": ph, "name": name, "pid": 1, "tid": tid,
                  "ts": ts * 1e6}
            if ph == "X":
                ev["dur"] = dur * 1e6
            if ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> int:
        """Write `to_chrome()` to `path`; returns the event count."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(self._ring)


TRACE = TraceRecorder()


def enable(capacity: Optional[int] = None) -> None:
    """Start recording on the process-global recorder."""
    TRACE.enable(capacity)


def disable() -> None:
    """Stop recording on the process-global recorder."""
    TRACE.disable()


def dump(path: str) -> int:
    """Export the process-global recorder to `path` (Chrome trace JSON)."""
    return TRACE.dump(path)


@contextlib.contextmanager
def profiler_window(logdir: Optional[str]):
    """Optionally bracket a block with `jax.profiler.trace(logdir)`.

    `logdir=None` (and any environment where the profiler is
    unavailable) degrades to a no-op, so call sites need no guards.
    """
    if not logdir:
        yield
        return
    try:
        import jax.profiler as _prof
        cm = _prof.trace(logdir)
    except Exception:
        yield
        return
    with cm:
        yield
