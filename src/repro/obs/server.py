"""Live /metrics endpoint: a stdlib http.server thread over the registry.

    from repro.obs import server as Osrv
    srv = Osrv.start_metrics_server(port)     # 0 = ephemeral
    ... serve traffic ...                     # GET /metrics while running
    srv.shutdown()

Routes:
  /metrics       Prometheus text exposition format (version 0.0.4)
  /metrics.json  the registry snapshot as JSON
  /healthz       200 "ok"

The server runs on a daemon thread and renders under the registry lock,
so scraping concurrent with engine stepping is safe; it never touches
the engine or device state (launch/serve.py --metrics-port wires it up).
"""
from __future__ import annotations

import http.server
import json
import threading
from typing import Optional

from repro.obs import metrics as Om

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(http.server.BaseHTTPRequestHandler):
    registry: Om.Registry = Om.REGISTRY

    def do_GET(self):  # noqa: N802 (http.server's casing)
        """Serve one GET against the metrics routes."""
        if self.path.split("?")[0] == "/metrics":
            body = self.registry.render_prometheus().encode()
            ctype = PROM_CONTENT_TYPE
        elif self.path.split("?")[0] == "/metrics.json":
            body = json.dumps(self.registry.snapshot()).encode()
            ctype = "application/json"
        elif self.path.split("?")[0] == "/healthz":
            body, ctype = b"ok\n", "text/plain"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):
        """Silence per-request access logging (CI output hygiene)."""


class MetricsServer:
    """An http.server thread exposing one registry (see module doc)."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: Optional[Om.Registry] = None):
        """Bind `host:port` (port 0 picks an ephemeral port)."""
        handler = type("Handler", (_Handler,),
                       {"registry": registry or Om.REGISTRY})
        self._httpd = http.server.ThreadingHTTPServer((host, port), handler)
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="obs-metrics", daemon=True)

    @property
    def port(self) -> int:
        """The bound TCP port (useful when constructed with port=0)."""
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        """Start serving on the daemon thread; returns self."""
        self._thread.start()
        return self

    def shutdown(self) -> None:
        """Stop the server thread and close the socket."""
        self._httpd.shutdown()
        self._httpd.server_close()


def start_metrics_server(port: int, host: str = "127.0.0.1",
                         registry: Optional[Om.Registry] = None
                         ) -> MetricsServer:
    """Start a MetricsServer on `host:port` and return it."""
    return MetricsServer(port, host, registry).start()
