"""Process-global metrics registry: counters, gauges, log histograms.

Zero-dependency (stdlib only) metric primitives for the serving and
training stack, with two export surfaces:

  * `Registry.snapshot()` — a JSON-able dict (the `/metrics.json`
    endpoint and the train launcher's `--metrics-interval` JSONL ticker);
  * `Registry.render_prometheus()` — Prometheus text exposition format
    version 0.0.4 (the `/metrics` endpoint behind
    `launch/serve.py --metrics-port`, see obs/server.py).

Metric naming contract (DESIGN.md §Observability): serving metrics are
`serve_*`, training metrics are `train_*`; durations are histograms in
seconds with `_seconds` suffix, monotone event counts are counters with
`_total`, instantaneous levels (pages, queue depth) are gauges.
Histograms default to log-spaced bucket bounds (`log_buckets`), because
serving latencies span 100µs decode steps to multi-second queue waits.

Everything here is cheap-by-default and host-side only: recording is a
dict update under a lock (no device sync can hide in a metric), and
`disable()` turns every record call into an early return — the bench's
metrics-on vs metrics-off overhead gate (perf_gate.py) holds the full
instrumented path within 3% of the disabled path.
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, List, Optional, Sequence, Tuple


def log_buckets(start: float = 1e-4, count: int = 20,
                factor: float = 2.0) -> Tuple[float, ...]:
    """Log-spaced histogram bounds: start * factor**i for i in [0, count).

    The default (1e-4, 20, 2.0) spans 100µs .. ~52s — decode-step to
    queue-wait scale on both CPU CI and real accelerators.
    """
    return tuple(start * factor ** i for i in range(count))


TIME_BUCKETS = log_buckets()


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats print as integers."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _escape(v) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labels_text(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in key) + "}"


class Counter:
    """A monotonically increasing count, optionally split by labels."""

    kind = "counter"

    def __init__(self, name: str, help: str, registry: "Registry"):
        """Create under `registry`; use `Registry.counter` instead."""
        self.name, self.help = name, help
        self._reg = registry
        self._values: Dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add `amount` (default 1) to the child selected by `labels`."""
        if not self._reg.enabled:
            return
        key = _label_key(labels)
        with self._reg._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        """Current count of the child selected by `labels` (0 if unseen)."""
        return self._values.get(_label_key(labels), 0.0)

    def _render(self, out: List[str]) -> None:
        for key in sorted(self._values):
            out.append(f"{self.name}{_labels_text(key)} "
                       f"{_fmt(self._values[key])}")
        if not self._values:
            out.append(f"{self.name} 0")

    def _snapshot(self) -> list:
        return [{"labels": dict(k), "value": v}
                for k, v in sorted(self._values.items())]

    def _reset(self) -> None:
        self._values.clear()


class Gauge(Counter):
    """An instantaneous level (pages in use, queue depth, train loss)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        """Set the child selected by `labels` to `value`."""
        if not self._reg.enabled:
            return
        with self._reg._lock:
            self._values[_label_key(labels)] = float(value)

    def dec(self, amount: float = 1.0, **labels) -> None:
        """Subtract `amount` from the child selected by `labels`."""
        self.inc(-amount, **labels)


class Histogram:
    """A bucketed value distribution with Prometheus `le` semantics.

    `observe(v)` lands in the first bucket whose bound satisfies
    v <= bound (values past the last bound count only toward +Inf).
    Rendered buckets are cumulative, as the text format requires.
    `quantile(q)` estimates a percentile by linear interpolation inside
    the covering bucket, clamped to the observed min/max — an
    approximation, good to one bucket's width (the bench's continuous
    ttft/tpot p50/p95 come from here).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, registry: "Registry",
                 buckets: Sequence[float] = TIME_BUCKETS):
        """Create under `registry`; use `Registry.histogram` instead."""
        self.name, self.help = name, help
        self._reg = registry
        self.bounds = tuple(sorted(float(b) for b in buckets))
        assert self.bounds, "histogram needs at least one bucket bound"
        # child: [per-bucket counts (+1 overflow), sum, count, min, max]
        self._values: Dict[tuple, list] = {}

    def _child(self, key: tuple) -> list:
        c = self._values.get(key)
        if c is None:
            c = self._values[key] = [[0] * (len(self.bounds) + 1),
                                     0.0, 0, float("inf"), float("-inf")]
        return c

    def observe(self, value: float, **labels) -> None:
        """Record one observation into the child selected by `labels`."""
        if not self._reg.enabled:
            return
        v = float(value)
        i = bisect.bisect_left(self.bounds, v)
        with self._reg._lock:
            c = self._child(_label_key(labels))
            c[0][i] += 1
            c[1] += v
            c[2] += 1
            c[3] = min(c[3], v)
            c[4] = max(c[4], v)

    def summary(self, **labels) -> dict:
        """{count, sum, min, max, mean} of the selected child."""
        c = self._values.get(_label_key(labels))
        if c is None:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0}
        return {"count": c[2], "sum": c[1], "min": c[3], "max": c[4],
                "mean": c[1] / c[2] if c[2] else 0.0}

    def quantile(self, q: float, **labels) -> float:
        """Approximate q-quantile (0..1) of the selected child, or 0.0
        when it has no observations."""
        c = self._values.get(_label_key(labels))
        if c is None or c[2] == 0:
            return 0.0
        counts, total, vmin, vmax = c[0], c[2], c[3], c[4]
        target = q * total
        cum = 0.0
        for i, n in enumerate(counts):
            if n == 0:
                continue
            if cum + n >= target:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = vmax if i == len(self.bounds) else self.bounds[i]
                frac = (target - cum) / n
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(vmin, min(vmax, est))
            cum += n
        return vmax

    def _render(self, out: List[str]) -> None:
        items = sorted(self._values.items()) or [((), self._child(()))]
        for key, c in items:
            cum = 0
            for bound, n in zip(self.bounds, c[0]):
                cum += n
                lk = key + (("le", _fmt(bound)),)
                out.append(f"{self.name}_bucket{_labels_text(lk)} {cum}")
            lk = key + (("le", "+Inf"),)
            out.append(f"{self.name}_bucket{_labels_text(lk)} {c[2]}")
            out.append(f"{self.name}_sum{_labels_text(key)} {_fmt(c[1])}")
            out.append(f"{self.name}_count{_labels_text(key)} {c[2]}")

    def _snapshot(self) -> list:
        out = []
        for key, c in sorted(self._values.items()):
            cum, buckets = 0, []
            for bound, n in zip(self.bounds, c[0]):
                cum += n
                buckets.append([bound, cum])
            out.append({"labels": dict(key), "count": c[2],
                        "sum": c[1], "min": c[3], "max": c[4],
                        "buckets": buckets})
        return out

    def _reset(self) -> None:
        self._values.clear()


class Registry:
    """A named collection of metrics with get-or-create registration.

    The process-global instance is `REGISTRY` (module helpers `counter`
    / `gauge` / `histogram` register there); tests that want isolation
    construct their own.  `enabled` gates every record call — flipping
    it is how the bench measures instrumentation overhead.
    """

    def __init__(self, enabled: bool = True):
        """Create an empty registry."""
        self._metrics: Dict[str, object] = {}
        self._lock = threading.RLock()
        self.enabled = enabled

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, self, **kw)
            assert type(m) is cls, \
                f"metric {name} already registered as {m.kind}"
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        """Get or create the counter `name`."""
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get or create the gauge `name`."""
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = TIME_BUCKETS) -> Histogram:
        """Get or create the histogram `name` (bounds fixed at creation)."""
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[object]:
        """The registered metric called `name`, or None."""
        return self._metrics.get(name)

    def reset(self) -> None:
        """Zero every metric's recorded values (registrations survive)."""
        with self._lock:
            for m in self._metrics.values():
                m._reset()

    def render_prometheus(self) -> str:
        """The whole registry in Prometheus text exposition format."""
        out: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    out.append(f"# HELP {name} {m.help}")
                out.append(f"# TYPE {name} {m.kind}")
                m._render(out)
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """JSON-able {name: {kind, help, values}} of the whole registry."""
        with self._lock:
            return {name: {"kind": m.kind, "help": m.help,
                           "values": m._snapshot()}
                    for name, m in sorted(self._metrics.items())}

    def values(self) -> dict:
        """Flat scalar view: counters/gauges by name (labelled children
        keyed `name{k=v,...}`), histograms as `name_count`/`name_sum`."""
        flat: Dict[str, float] = {}
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                if isinstance(m, Histogram):
                    for entry in m._snapshot():
                        lt = _labels_text(_label_key(entry["labels"]))
                        flat[f"{name}_count{lt}"] = entry["count"]
                        flat[f"{name}_sum{lt}"] = entry["sum"]
                else:
                    for key, v in sorted(m._values.items()):
                        flat[f"{name}{_labels_text(key)}"] = v
        return flat


REGISTRY = Registry()


def counter(name: str, help: str = "") -> Counter:
    """Get or create `name` on the process-global registry."""
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    """Get or create `name` on the process-global registry."""
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "",
              buckets: Sequence[float] = TIME_BUCKETS) -> Histogram:
    """Get or create `name` on the process-global registry."""
    return REGISTRY.histogram(name, help, buckets=buckets)


def enable() -> None:
    """Turn recording on for the process-global registry (the default)."""
    REGISTRY.enabled = True


def disable() -> None:
    """Turn every record call on the global registry into a no-op."""
    REGISTRY.enabled = False


def enabled() -> bool:
    """Whether the process-global registry is recording."""
    return REGISTRY.enabled


def jsonl_line(extra: Optional[dict] = None) -> str:
    """One compact JSON line of the global registry's flat values (the
    train launcher's machine-readable ticker); `extra` keys merge in
    first so they cannot be shadowed by metric names."""
    payload = dict(extra or {})
    payload.update(REGISTRY.values())
    return json.dumps(payload, separators=(",", ":"), sort_keys=True)
