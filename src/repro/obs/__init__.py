"""Zero-dependency observability for the serving/training stack.

Three pieces (DESIGN.md §Observability):

  * `obs.metrics` — process-global counters / gauges / log-bucketed
    histograms, JSON-snapshotable and rendered in Prometheus text
    format, served live by `obs.server` (`launch/serve.py
    --metrics-port`) and ticked as JSONL by `launch/train.py
    --metrics-interval`;
  * `obs.trace` — per-request event timelines + engine-step phase
    spans in a bounded ring, exportable as Chrome trace-event JSON
    (`Engine.dump_trace`, `--trace`);
  * `obs.clock()` — the injectable wall clock every latency timestamp
    reads, so tests can install `FakeClock` instead of sleeping.

Everything records on the host, outside jitted regions, and is
cheap-by-default: metrics are dict updates behind an `enabled` flag,
tracing is off until enabled, and perf_gate.py holds the metrics-on
serving path within 3% of metrics-off.
"""
from repro.obs import metrics, trace
from repro.obs.clock import FakeClock, clock, get_clock, set_clock

__all__ = ["metrics", "trace", "clock", "set_clock", "get_clock",
           "FakeClock"]
