"""Genomics data (paper Sec. 5 reproduction, offline).

Synthetic "reference genome" with planted structure:
  * background: order-0 ACGT with GC-bias drift,
  * motifs: planted TATA-box / CpG-island-like promoter motifs upstream of
    "gene" sites — giving the promoter-prediction task (Tab. 6) real signal,
  * BPE-ish tokenizer: greedy longest-match over a frequency-built merge
    table (the paper uses sentencepiece at ~8.78 bp/token; we build an
    equivalent fixed-size subword table over ACGT).

Tasks mirrored from the paper:
  * MLM pretraining over long DNA contexts (Tab. 5: BPC),
  * promoter region classification (Tab. 6): fragment -> {promoter, not}.
"""
from __future__ import annotations

import dataclasses

import numpy as np

BASES = np.array(list("ACGT"))
PROMOTER_MOTIF = "TATAAA"          # TATA box
CPG = "CGCGCG"


@dataclasses.dataclass(frozen=True)
class GenomeConfig:
    length: int = 1_000_000
    promoter_rate: float = 0.0005
    seed: int = 7


def synthesize_genome(cfg: GenomeConfig):
    """Returns (genome string, promoter site indices)."""
    rng = np.random.default_rng(cfg.seed)
    # GC-content drift: mixture of two base distributions over segments
    n = cfg.length
    rng.integers(2000, 10000)        # segment-length draw advances rng
    probs_at = np.array([0.3, 0.2, 0.2, 0.3])
    probs_gc = np.array([0.2, 0.3, 0.3, 0.2])
    out = []
    pos = 0
    while pos < n:
        ln = int(rng.integers(2000, 10000))
        p = probs_at if rng.random() < 0.5 else probs_gc
        out.append(rng.choice(4, size=ln, p=p))
        pos += ln
    genome = np.concatenate(out)[:n]
    # plant promoters: motif + CpG island upstream of random sites
    sites = rng.choice(n - 200, size=int(n * cfg.promoter_rate), replace=False)
    motif = np.array([_b2i(c) for c in PROMOTER_MOTIF + CPG])
    for s in sites:
        genome[s:s + len(motif)] = motif
    return "".join(BASES[genome]), np.sort(sites)


def _b2i(c):
    return "ACGT".index(c)


class DnaTokenizer:
    """Greedy longest-match subword tokenizer over ACGT (BPE-equivalent)."""

    def __init__(self, genome: str, vocab_size: int = 4096, max_len: int = 8):
        # count frequent k-mers, keep the most frequent as vocab
        counts: dict = {}
        step = 16
        for k in (2, 3, 4, 6, 8):
            if k > max_len:
                continue
            for i in range(0, min(len(genome) - k, 400_000), step):
                w = genome[i:i + k]
                counts[w] = counts.get(w, 0) + 1
        best = sorted(counts, key=lambda w: (-len(w) * counts[w]))
        pieces = ["<pad>", "<mask>", "<cls>", "<sep>", "A", "C", "G", "T"]
        pieces += [w for w in best if len(w) > 1][:vocab_size - len(pieces)]
        self.vocab = {w: i for i, w in enumerate(pieces)}
        self.inv = pieces
        self.max_len = max(len(w) for w in pieces)
        self.pad, self.mask, self.cls, self.sep = 0, 1, 2, 3

    @property
    def vocab_size(self):
        return len(self.inv)

    def encode(self, s: str) -> np.ndarray:
        out = []
        i = 0
        n = len(s)
        while i < n:
            for ln in range(min(self.max_len, n - i), 0, -1):
                tid = self.vocab.get(s[i:i + ln])
                if tid is not None:
                    out.append(tid)
                    i += ln
                    break
            else:
                i += 1            # unknown char: skip
        return np.array(out, dtype=np.int32)


def promoter_dataset(genome: str, sites: np.ndarray, tok: DnaTokenizer,
                     n_examples: int = 512, frag: int = 1000, seed: int = 3,
                     seq_len: int = 256):
    """Balanced fragments -> (tokens (N, seq_len), labels (N,)).

    Positives are centered on planted promoter sites; negatives are random
    fragments (paper: EPDnew-style construction)."""
    rng = np.random.default_rng(seed)
    half = n_examples // 2
    X = np.zeros((n_examples, seq_len), dtype=np.int32)
    y = np.zeros(n_examples, dtype=np.int32)
    pos_sites = rng.choice(sites, size=half, replace=len(sites) < half)
    for i, s in enumerate(pos_sites):
        start = max(0, int(s) - frag // 2)
        toks = tok.encode(genome[start:start + frag])[:seq_len]
        X[i, :len(toks)] = toks
        y[i] = 1
    for i in range(half, n_examples):
        while True:
            start = int(rng.integers(0, len(genome) - frag))
            if not ((sites > start) & (sites < start + frag)).any():
                break
        toks = tok.encode(genome[start:start + frag])[:seq_len]
        X[i, :len(toks)] = toks
    perm = rng.permutation(n_examples)
    return X[perm], y[perm]


def mlm_batches(genome: str, tok: DnaTokenizer, batch: int, seq_len: int,
                seed: int = 11):
    """Infinite MLM batch generator over the genome."""
    rng = np.random.default_rng(seed)
    enc_cache = tok.encode(genome[:600_000])
    while True:
        B = batch
        tokens = np.zeros((B, seq_len), dtype=np.int32)
        for b in range(B):
            o = int(rng.integers(0, len(enc_cache) - seq_len - 1))
            tokens[b] = enc_cache[o:o + seq_len]
        labels = tokens.copy()
        mask = rng.random((B, seq_len)) < 0.15
        inp = tokens.copy()
        inp[mask] = tok.mask
        yield {"tokens": inp, "labels": labels,
               "loss_mask": mask.astype(np.float32)}
        step += 1
