"""Data pipeline: deterministic synthetic corpora, document packing, host
sharding, and background prefetch.

The container is offline, so corpora are synthetic but *structured* (Zipfian
unigrams + a k-th order Markov chain) so models have something learnable —
losses drop well below the unigram entropy, which the examples assert.

Determinism & fault tolerance: every batch is a pure function of
(seed, host_id, num_hosts, step), so a restarted or replaced host resumes
exactly the stream it owned — no data loss, no duplication (straggler /
elastic-restart story, see ft/).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    batch_size: int = 8              # per-host
    seed: int = 1234
    markov_order: int = 2
    zipf_a: float = 1.2
    num_hosts: int = 1
    host_id: int = 0
    mlm: bool = False                # MLM masking (the paper's objective)
    mlm_rate: float = 0.15
    mask_token: int = 3
    doc_len_range: tuple = (64, 512)
    pad_token: int = 0
    # long-range structure: documents carry a topic-head token that selects
    # the bigram successor table — predicting a token then requires BOTH the
    # previous token (local) and the document head (long-range reach).  This
    # is the mechanism behind the paper's Table-1 ordering (W < R+W < R+W+G)
    # and Fig-8 (longer context resolves more heads).
    num_topics: int = 0
    single_doc_rows: bool = False    # True: one doc/row, head at position 0


class SyntheticLM:
    """Markov-chain token stream with document packing."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.SeedSequence([cfg.seed])
        rng = np.random.default_rng(root)
        v = cfg.vocab_size
        # Zipfian unigram over a capped alphabet for tractable transitions
        self._alpha = min(v, 4096)
        ranks = np.arange(1, self._alpha + 1, dtype=np.float64)
        self._unigram = ranks ** (-cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # deterministic "hash" transition: next ~ f(prev tokens) + noise
        self._mix = rng.integers(1, 2**31 - 1, size=cfg.markov_order)

    def _doc(self, rng, topic: int = 0) -> np.ndarray:
        lo, hi = self.cfg.doc_len_range
        n = int(rng.integers(lo, hi + 1))
        toks = np.empty(n, dtype=np.int64)
        prev = int(rng.choice(self._alpha, p=self._unigram))
        # 85% deterministic bigram successor + 15% Zipf noise: cheap to
        # generate, genuinely learnable (a bigram table), with ~1.0 nat of
        # irreducible entropy so loss curves look like real LM training.
        det = rng.random(n) < 0.85
        noise = rng.choice(self._alpha, size=n, p=self._unigram)
        mix = 31 + 13 * topic                # topic-dependent successor fn
        for i in range(n):
            toks[i] = ((prev * mix + 7) % self._alpha) if det[i] else noise[i]
            prev = int(toks[i])
        toks = toks % self.cfg.vocab_size
        lo = 4 + self.cfg.num_topics         # reserve specials + topic heads
        toks[toks < lo] += lo
        return toks

    def batch(self, step: int) -> dict:
        """Pure function of (cfg, step): packed (B, S) tokens + labels."""
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence(
            [cfg.seed, cfg.host_id, cfg.num_hosts, step]))
        B, S = cfg.batch_size, cfg.seq_len
        out = np.full((B, S + 1), cfg.pad_token, dtype=np.int32)

        def one_doc(rng):
            if cfg.num_topics > 0:
                topic = int(rng.integers(cfg.num_topics))
                head = np.array([4 + (topic % (cfg.vocab_size - 4))],
                                dtype=np.int64)
                return np.concatenate([head, self._doc(rng, topic)])
            return self._doc(rng)

        for b in range(B):
            if cfg.single_doc_rows and cfg.num_topics > 0:
                doc = one_doc(rng)
                while len(doc) < S + 1:
                    topic = int(doc[0]) - 4
                    doc = np.concatenate([doc, self._doc(rng, topic)])
                out[b] = doc[:S + 1]
                continue
            filled = 0
            first = True
            while filled < S + 1:
                doc = one_doc(rng)
                if first:
                    # rows start mid-document (sliding-window packing): the
                    # first doc's head may be cut off — short contexts then
                    # often cannot resolve it (Fig-8 mechanism)
                    doc = doc[int(rng.integers(0, max(len(doc) - 8, 1))):]
                    first = False
                take = min(len(doc), S + 1 - filled)
                out[b, filled:filled + take] = doc[:take]
                filled += take
        if cfg.mlm:
            tokens = out[:, :S].copy()
            labels = out[:, :S].copy()
            mask = rng.random((B, S)) < cfg.mlm_rate
            # BERT 80/10/10 corruption
            r = rng.random((B, S))
            tokens[mask & (r < 0.8)] = cfg.mask_token
            rnd = rng.integers(4, cfg.vocab_size, size=(B, S))
            repl = mask & (r >= 0.8) & (r < 0.9)
            tokens[repl] = rnd[repl]
            return {"tokens": tokens, "labels": labels,
                    "loss_mask": mask.astype(np.float32)}
        return {"tokens": out[:, :S], "labels": out[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (the host-side input pipeline)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(source.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
