"""Markdown link/anchor checker for the repo docs (stdlib only).

Run by the CI lint job:

    python tools/check_docs.py README.md DESIGN.md

Checks every inline link `[text](target)`:
  * http(s)/mailto targets are skipped (no network in CI);
  * relative file targets must exist on disk;
  * `file#anchor` / `#anchor` targets must match a heading slug in the
    target file (GitHub slug rules: lowercase, punctuation stripped,
    spaces to hyphens).
Exits non-zero listing every broken link.
"""
import pathlib
import re
import sys

LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    h = re.sub(r"`([^`]*)`", r"\1", heading)          # strip inline code
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)  # drop punctuation
    return h.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    """All heading anchors defined in a markdown file."""
    out = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING.match(line)
        if m:
            out.add(slugify(m.group(1)))
    return out


def check(files):
    """Return a list of (file, link, reason) for every broken link."""
    errors = []
    for name in files:
        doc = pathlib.Path(name)
        if not doc.is_file():
            errors.append((name, "-", "doc file missing"))
            continue
        in_fence = False
        for line in doc.read_text().splitlines():
            if CODE_FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                file_part, _, anchor = target.partition("#")
                base = (doc.parent / file_part) if file_part else doc
                if not base.exists():
                    errors.append((name, target, "missing file"))
                    continue
                if anchor and base.suffix == ".md":
                    if slugify(anchor) not in anchors_of(base):
                        errors.append((name, target, "missing anchor"))
    return errors


def main(argv):
    files = argv or ["README.md", "DESIGN.md"]
    errors = check(files)
    for doc, link, why in errors:
        print(f"{doc}: broken link `{link}` ({why})")
    print(f"check_docs: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
