"""PatternPolicy contract tests (DESIGN.md §Pattern policies).

Every registered policy must emit the artifacts the stack consumes —
forward slot maps whose masked slots match the dense-mask oracle, a
transposed map that is the exact inverse of the forward map, causal rows
that are prefix-stable under growing cache length, and a diag_slot that
names the only self-referencing slot — plus golden-hash regression pinning
the default policy bit-identical to the pre-refactor builder.
"""
import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional extra — see requirements.txt
    from _prop import given, settings, st

from repro.core import patterns

POLICIES = ("bigbird", "importance", "littlebird")


def cfg_of(b=16, w=3, g=2, r=2, causal=False, seed=0, pattern="bigbird"):
    return patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                  num_global_blocks=g, num_random_blocks=r,
                                  causal=causal, seed=seed, pattern=pattern)


def _h(*arrs):
    m = hashlib.sha256()
    for a in arrs:
        m.update(np.ascontiguousarray(a).tobytes())
    return m.hexdigest()[:16]


# hashes of build_pattern/transposed_pattern outputs captured at the commit
# BEFORE the PatternPolicy refactor: the default policy must stay
# bit-identical (the serving digest gate depends on it).  Keys are
# (block, w, g, r, causal, seed, seq_len, layer); covers the paper base
# config, the serving-bench config and the smoke config.
GOLDEN_DEFAULT = {
    (64, 3, 2, 3, True, 0, 1024, 0): ("099cc6655d25f1ea", "882a7b6e099854f9"),
    (64, 3, 2, 3, False, 0, 1024, 0): ("89a4c5a450f059c1", "01fa4f94aa06efcd"),
    (64, 3, 2, 3, True, 0, 4096, 0): ("03ab4a9829ee6a35", "ba23ae6a5327dc0f"),
    (32, 3, 1, 1, True, 0, 512, 0): ("a4aa6d3e403b971d", "0e1aa946884bc11d"),
    (16, 3, 1, 1, True, 0, 256, 0): ("a4aa6d3e403b971d", "0e1aa946884bc11d"),
    (16, 3, 1, 2, False, 0, 256, 0): ("06465e1f6f2f85dd", "f3af553415a45bc2"),
    (64, 3, 2, 3, True, 7, 2048, 2): ("98e7ac9d6399e63a", "9c2044b26f5925f5"),
}


def test_default_policy_bitwise_golden():
    """The PatternPolicy refactor is a no-op for the default policy: the
    exact bytes of the slot maps (and transposed maps) match hashes
    recorded from the pre-refactor builder."""
    for (b, w, g, r, causal, seed, S, layer), want in GOLDEN_DEFAULT.items():
        cfg = cfg_of(b=b, w=w, g=g, r=r, causal=causal, seed=seed)
        pat = patterns.build_pattern(cfg, S, layer=layer)
        tq, tm = patterns.transposed_pattern(cfg, S, layer=layer)
        got = (_h(pat.key_blocks, pat.key_mask), _h(tq, tm))
        assert got == want, (b, w, g, r, causal, seed, S, layer)


def test_registry_contents():
    assert set(POLICIES) <= set(patterns.registered_policies())
    with pytest.raises(ValueError):
        patterns.get_policy("nope")
    with pytest.raises(ValueError):
        cfg_of(pattern="nope")


def test_default_pattern_field_is_equality_neutral():
    """Configs written before the pattern field existed must compare (and
    hash) equal to configs that spell the default explicitly — engine
    graph keys and the build_pattern cache key on the config."""
    a = patterns.BigBirdConfig(block_size=16, causal=True)
    b = patterns.BigBirdConfig(block_size=16, causal=True, pattern="bigbird")
    assert a == b and hash(a) == hash(b)
    assert a != dataclasses.replace(a, pattern="littlebird")


@pytest.mark.parametrize("pol", POLICIES)
def test_policy_slot_budget_matched(pol):
    """Every policy spends the same g+w+r slot budget (matched wall-clock)."""
    cfg = cfg_of(causal=True, pattern=pol)
    pat = patterns.build_pattern(cfg, 256)
    assert pat.slots == (cfg.num_global_blocks + cfg.num_window_blocks
                         + cfg.num_random_blocks)
    assert patterns.min_blocks(cfg) == pat.slots
    assert patterns.fits(cfg, pat.slots) and not patterns.fits(cfg, -1)
    with pytest.raises(ValueError):
        cfg.validate((pat.slots - 1) * cfg.block_size)


@pytest.mark.parametrize("pol", POLICIES)
def test_policy_diag_slot_is_only_self_reference(pol):
    """Causal kernels refine exactly one slot with the triangular mask:
    the policy's diag_slot must name it, and no other live slot of a
    non-global query row may reference the query's own block."""
    for causal in (False, True):
        cfg = cfg_of(causal=causal, pattern=pol)
        pat = patterns.build_pattern(cfg, 512)
        ds = patterns.diag_slot(cfg)
        g = cfg.num_global_blocks
        for j in range(g, pat.num_blocks):
            self_slots = [t for t in range(pat.slots)
                          if pat.key_mask[j, t] and pat.key_blocks[j, t] == j]
            if causal:
                assert self_slots == [ds], (j, self_slots, ds)
            else:
                assert ds == -1 and len(self_slots) <= 1


@pytest.mark.parametrize("pol", POLICIES)
def test_policy_causal_rows_prefix_stable(pol):
    """Paged decode rebuilds the pattern at the logical cache length as it
    grows; earlier rows must never change, for every policy."""
    @settings(max_examples=10, deadline=None)
    @given(nb1=st.integers(8, 16), grow=st.integers(1, 24),
           seed=st.integers(0, 3))
    def prop(nb1, grow, seed):
        cfg = cfg_of(b=16, causal=True, seed=seed, pattern=pol)
        p1 = patterns.build_pattern(cfg, nb1 * 16)
        p2 = patterns.build_pattern(cfg, (nb1 + grow) * 16)
        assert (p1.key_blocks == p2.key_blocks[:nb1]).all()
        assert (p1.key_mask == p2.key_mask[:nb1]).all()
    prop()


@pytest.mark.parametrize("pol", POLICIES)
def test_policy_transposed_is_exact_inverse(pol):
    """(tq, tmask) must contain exactly the live non-global slots of the
    non-global query rows, per key block, padding masked."""
    @settings(max_examples=10, deadline=None)
    @given(nb=st.integers(8, 24), causal=st.booleans(), g=st.integers(0, 2))
    def prop(nb, causal, g):
        cfg = cfg_of(b=8, g=g, causal=causal, pattern=pol)
        if patterns.min_blocks(cfg) > nb:
            return
        pat = patterns.build_pattern(cfg, nb * 8)
        tq, tmask = patterns.transposed_pattern(cfg, nb * 8)
        fwd = {}
        for j in range(g, nb):
            for t in range(g, pat.slots):
                if pat.key_mask[j, t]:
                    fwd.setdefault(int(pat.key_blocks[j, t]), []).append(j)
        for i in range(nb):
            assert sorted(tq[i][tmask[i]].tolist()) == sorted(fwd.get(i, []))
        assert (tq[~tmask] == 0).all()
    prop()


@pytest.mark.parametrize("pol", POLICIES)
def test_policy_key_mask_semantics(pol):
    """Key-mask exactness for every policy: live slots are in range, never
    duplicated, never in the future (causal, non-global rows), and the
    global slots are always the first g indices."""
    @settings(max_examples=10, deadline=None)
    @given(nb=st.integers(8, 24), causal=st.booleans(), seed=st.integers(0, 3))
    def prop(nb, causal, seed):
        cfg = cfg_of(b=8, causal=causal, seed=seed, pattern=pol)
        if patterns.min_blocks(cfg) > nb:
            return
        g = cfg.num_global_blocks
        pat = patterns.build_pattern(cfg, nb * 8)
        for j in range(nb):
            live = pat.key_blocks[j][pat.key_mask[j]]
            assert (live >= 0).all() and (live < nb).all()
            assert len(set(live.tolist())) == len(live), f"dup in row {j}"
            if causal and j >= g:
                assert (live <= j).all()
            assert pat.key_mask[j, :g].all()
            assert (pat.key_blocks[j, :g] == np.arange(g)).all()
        # the dense oracle derived from the pattern keeps the star graph
        # and (causal) lower-triangularity — the invariants Theorem 1 needs
        M = patterns.dense_mask(pat)
        gg = g * 8
        if causal:
            # star graph survives up to the causal triangle
            assert M[:gg, :1].all() or g == 0
            assert np.tril(M)[:, :gg].sum() == np.tril(
                np.ones_like(M))[:, :gg].sum() or g == 0
            assert not np.triu(M, k=1).any()
        else:
            assert M[:gg, :].all() or g == 0
            assert M[:, :gg].all() or g == 0
    prop()


@pytest.mark.parametrize("pol", ("importance", "littlebird"))
def test_non_default_policies_differ_from_default(pol):
    """The policies are real alternatives: same budget, different graph."""
    S = 512
    base = patterns.build_pattern(cfg_of(causal=True), S)
    alt = patterns.build_pattern(cfg_of(causal=True, pattern=pol), S)
    assert not (np.where(base.key_mask, base.key_blocks, -1)
                == np.where(alt.key_mask, alt.key_blocks, -1)).all()


def test_importance_selection_is_deterministic_and_dyadic():
    """The importance proxy is a pure function of the query block: exact
    power-of-two distances rank first, larger reach preferred."""
    cfg = cfg_of(b=16, w=1, g=1, r=3, causal=True, pattern="importance")
    pat = patterns.build_pattern(cfg, 64 * 16)
    pat2 = patterns.build_pattern(
        dataclasses.replace(cfg, seed=99), 64 * 16)   # seed-independent
    assert (pat.key_blocks == pat2.key_blocks).all()
    j = 40
    picks = set(pat.key_blocks[j][pat.key_mask[j]][2:].tolist())
    dists = sorted(j - p for p in picks)
    assert all(d & (d - 1) == 0 for d in dists), dists   # powers of two
    assert dists == sorted(dists, reverse=False) and dists[-1] >= 16


def test_littlebird_is_pure_window_plus_globals():
    """The littlebird layout folds the random budget into the window: every
    non-global live slot is within w+r blocks left of the query (causal)."""
    cfg = cfg_of(b=16, causal=True, pattern="littlebird")
    we = cfg.num_window_blocks + cfg.num_random_blocks
    pat = patterns.build_pattern(cfg, 512)
    g = cfg.num_global_blocks
    for j in range(g, pat.num_blocks):
        live = pat.key_blocks[j, g:][pat.key_mask[j, g:]]
        assert ((j - live >= 0) & (j - live < we)).all()
    # even non-causal windows are accepted (asymmetric split)
    even = cfg_of(w=2, r=2, causal=False, pattern="littlebird")
    pat_e = patterns.build_pattern(even, 256)
    assert pat_e.slots == 2 + 2 + 2


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("causal", (False, True))
def test_policy_grad_parity_through_fused_kernels(pol, causal):
    """jax.grad parity: the fused Pallas custom_vjp path must match the
    dense-mask reference for every policy (frozen selection trains
    straight through the kernels)."""
    from repro.core import ref_attention as R
    from repro.kernels import ops
    B, Hq, Hkv, S, d = 1, 2, 1, 128, 8
    cfg = cfg_of(b=16, w=3, g=1, r=2, causal=causal, pattern=pol)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)

    def loss(fn):
        return lambda args: jnp.sum(fn(*args) ** 2)

    ref = R.bigbird_attention_reference(q, k, v, cfg)
    out = ops.bigbird_attention_fused(q, k, v, cfg)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-4
    g_ref = jax.grad(loss(
        lambda a, b, c: R.bigbird_attention_reference(a, b, c, cfg)))((q, k, v))
    g_fus = jax.grad(loss(
        lambda a, b, c: ops.bigbird_attention_fused(a, b, c, cfg)))((q, k, v))
    for gr, gf in zip(g_ref, g_fus):
        assert float(jnp.max(jnp.abs(gr - gf))) < 2e-3


@pytest.mark.parametrize("pol", POLICIES)
def test_policy_paged_decode_matches_forward(pol):
    """Bounded decode through the paged cache must equal the teacher-forced
    forward for every policy (the decode graph consumes only the policy's
    slot maps — nothing else may change)."""
    from repro.core.attention import AttentionSpec
    from repro.models import decode as D
    from repro.models import model as M
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1, pattern=pol)
    cfg = M.ModelConfig(name=f"pol-{pol}", d_model=32, num_layers=2,
                        num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                        attn=bb, dtype=jnp.float32, scan_layers=False,
                        remat="none", loss_chunk=32)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    B, S, MAX = 1, 56, 64
    toks = jax.random.randint(key, (B, S), 4, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    _, cache = D.prefill(params, cfg, batch, MAX)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    full = M.logits_fn(params, cfg, dict(batch, tokens=toks2, labels=toks2))
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S]))) < 2e-3
