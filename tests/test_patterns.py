"""Pattern invariants — including hypothesis property tests of the paper's
theoretical structure (§3): star-graph containment, no-duplicate slots,
causality, and window/global coverage."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional extra — see requirements.txt
    from _prop import given, settings, st

from repro.core import patterns


def cfg_of(b=16, w=3, g=2, r=2, causal=False, seed=0):
    return patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                  num_global_blocks=g, num_random_blocks=r,
                                  causal=causal, seed=seed)


def test_slot_layout_counts():
    pat = patterns.build_pattern(cfg_of(), 256)
    assert pat.key_blocks.shape == (16, 7)          # g + w + r
    assert pat.num_blocks == 16


def test_no_duplicate_live_slots():
    for causal in (False, True):
        pat = patterns.build_pattern(cfg_of(causal=causal), 512)
        for j in range(pat.num_blocks):
            live = pat.key_blocks[j][pat.key_mask[j]]
            assert len(set(live.tolist())) == len(live), f"dup in row {j}"


def test_dense_mask_star_graph():
    """Theorem 1 requires the pattern to contain the star graph: global
    rows/cols fully connected."""
    cfg = cfg_of()
    pat = patterns.build_pattern(cfg, 256)
    M = patterns.dense_mask(pat)
    g = cfg.num_global_blocks * cfg.block_size
    assert M[:g, :].all(), "global rows must attend everywhere"
    assert M[:, :g].all(), "everyone must attend to global tokens"


def test_causal_mask_is_lower_triangular():
    cfg = cfg_of(causal=True)
    pat = patterns.build_pattern(cfg, 256)
    M = patterns.dense_mask(pat)
    assert not np.triu(M, k=1).any()


def test_window_covers_self_and_neighbors():
    cfg = cfg_of(w=3, g=1, r=0)
    pat = patterns.build_pattern(cfg, 256)
    M = patterns.dense_mask(pat)
    b = cfg.block_size
    for j in range(2, pat.num_blocks - 1):          # interior blocks
        i = j * b
        assert M[i, i], "self"
        assert M[i, i - b], "left neighbor block"
        assert M[i, i + b], "right neighbor block"


def test_connectivity_short_paths():
    """Expander property proxy: with globals, any i->j path length <= 2."""
    cfg = cfg_of(g=1, r=1)
    pat = patterns.build_pattern(cfg, 512)
    A = patterns.dense_mask(pat).astype(np.int64)
    two_hop = ((A @ A) > 0) | (A > 0)
    assert two_hop.all(), "global tokens give diameter <= 2"


def test_validate_rejects_oversized_pattern():
    with pytest.raises(ValueError):
        cfg_of().validate(3 * 16)                    # 3 blocks < g+w+r
    with pytest.raises(ValueError):
        cfg_of().validate(100)                       # not divisible


@settings(max_examples=30, deadline=None)
@given(
    nb=st.integers(8, 40),
    b=st.sampled_from([8, 16, 64]),
    w=st.sampled_from([1, 3, 5]),
    g=st.integers(0, 2),
    r=st.integers(0, 3),
    causal=st.booleans(),
    seed=st.integers(0, 5),
)
def test_pattern_properties(nb, b, w, g, r, causal, seed):
    if g + w + r > nb:
        return
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                 num_global_blocks=g, num_random_blocks=r,
                                 causal=causal, seed=seed)
    pat = patterns.build_pattern(cfg, nb * b)
    assert pat.key_blocks.shape == (nb, g + w + r)
    # all indices in range
    assert (pat.key_blocks[pat.key_mask] >= 0).all()
    assert (pat.key_blocks[pat.key_mask] < nb).all()
    # no duplicates among live slots
    for j in range(nb):
        live = pat.key_blocks[j][pat.key_mask[j]]
        assert len(set(live.tolist())) == len(live)
    # causal: no live slot points to a future block — except the global
    # slots of rows j < g, which are densely recomputed by every impl
    # (paper: "the first row-block is computed by direct multiplication")
    if causal:
        for j in range(g, nb):
            live = pat.key_blocks[j][pat.key_mask[j]]
            assert (live <= j).all()
    # determinism
    pat2 = patterns.build_pattern(cfg, nb * b)
    assert (pat.key_blocks == pat2.key_blocks).all()
    # window slot for offset 0 is always live for j >= g
    M = patterns.dense_mask(pat)
    for j in range(g, nb):
        assert M[j * b + b - 1, j * b], "diagonal block reachable"


@settings(max_examples=15, deadline=None)
@given(seed1=st.integers(0, 3), seed2=st.integers(4, 8))
def test_random_blocks_vary_with_seed(seed1, seed2):
    p1 = patterns.build_pattern(cfg_of(seed=seed1, r=3), 1024)
    p2 = patterns.build_pattern(cfg_of(seed=seed2, r=3), 1024)
    g, w = 2, 3
    assert (p1.key_blocks[:, g + w:] != p2.key_blocks[:, g + w:]).any()


@settings(max_examples=25, deadline=None)
@given(
    nb1=st.integers(8, 16),
    grow=st.integers(1, 24),
    w=st.sampled_from([1, 3, 5]),
    g=st.integers(0, 2),
    r=st.integers(0, 3),
    seed=st.integers(0, 5),
)
def test_causal_pattern_rows_prefix_stable(nb1, grow, w, g, r, seed):
    """Causal pattern rows must not change as S grows (prefix stability):
    this is what makes prefill and bounded decode attend the same graph."""
    b = 16
    if g + w + r > nb1:
        return
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                 num_global_blocks=g, num_random_blocks=r,
                                 causal=True, seed=seed)
    p1 = patterns.build_pattern(cfg, nb1 * b)
    p2 = patterns.build_pattern(cfg, (nb1 + grow) * b)
    assert (p1.key_blocks == p2.key_blocks[:nb1]).all()
    assert (p1.key_mask == p2.key_mask[:nb1]).all()


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(8, 32),
    w=st.sampled_from([1, 3, 5]),
    g=st.integers(0, 2),
    r=st.integers(0, 3),
    causal=st.booleans(),
    seed=st.integers(0, 5),
)
def test_key_mask_exactly_marks_dead_slots(nb, w, g, r, causal, seed):
    """key_mask must be *exact*: a slot is dead iff it is out-of-range
    (causal past-the-start window), a duplicate of a global slot, or an
    unfillable random slot — and every live index is in range."""
    b = 8
    if g + w + r > nb or (not causal and w % 2 == 0):
        return
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                 num_global_blocks=g, num_random_blocks=r,
                                 causal=causal, seed=seed)
    pat = patterns.build_pattern(cfg, nb * b)
    offs = patterns._window_offsets(cfg)
    for j in range(nb):
        live = pat.key_blocks[j][pat.key_mask[j]]
        # every live slot index is in range (causal: in the past for j >= g)
        assert (live >= 0).all() and (live < nb).all()
        if causal and j >= g:
            assert (live <= j).all()
        # global slots: always live, indices 0..g-1
        assert pat.key_mask[j, :g].all()
        assert (pat.key_blocks[j, :g] == np.arange(g)).all()
        # window slots: dead iff out-of-range (causal) or global-duplicate
        for t in range(w):
            tgt = j + int(offs[t])
            wrapped = max(tgt, 0) if causal else tgt % nb
            expect = (tgt >= 0 if causal else True) and wrapped >= g
            assert bool(pat.key_mask[j, g + t]) == expect, (j, t)
            if expect:
                assert pat.key_blocks[j, g + t] == (
                    min(wrapped, nb - 1) if causal else wrapped)
        # random slots: exactly min(r, #free candidates) are live, and each
        # live one is a fresh (non-duplicate) in-range candidate
        hi = j if causal else nb
        win_idx = {int(np.clip(j + o, 0, nb - 1)) if causal else
                   int((j + o) % nb) for o in offs}
        forbidden = set(range(g)) | win_idx | {j}
        n_free = len([c for c in range(g, hi) if c not in forbidden])
        rand_live = pat.key_mask[j, g + w:]
        assert rand_live.sum() == min(r, n_free), (j, rand_live)
        picks = pat.key_blocks[j, g + w:][rand_live]
        assert len(set(picks.tolist())) == len(picks)
        for c in picks:
            assert g <= c < hi and int(c) not in forbidden


@settings(max_examples=15, deadline=None)
@given(
    nb=st.integers(8, 24),
    w=st.sampled_from([1, 3]),
    g=st.integers(0, 2),
    r=st.integers(0, 2),
    causal=st.booleans(),
)
def test_transposed_pattern_is_exact_inverse(nb, w, g, r, causal):
    """The backward-pass transposed map must contain exactly the live
    non-global slots of the non-global query rows (per key block, padded
    with mask) — global query rows' sparse gradients are identically zero
    (dense recompute), so their edges are excluded."""
    b = 8
    if g + w + r > nb or (not causal and w % 2 == 0):
        return
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                 num_global_blocks=g, num_random_blocks=r,
                                 causal=causal)
    pat = patterns.build_pattern(cfg, nb * b)
    tq, tmask = patterns.transposed_pattern(cfg, nb * b)
    assert tq.shape == tmask.shape and tq.shape[0] == nb
    # forward multiset of (key block -> query block) edges: non-global
    # slots of non-global query rows
    fwd = {}
    for j in range(g, nb):
        for t in range(g, pat.slots):
            if pat.key_mask[j, t]:
                fwd.setdefault(int(pat.key_blocks[j, t]), []).append(j)
    for i in range(nb):
        got = sorted(tq[i][tmask[i]].tolist())
        assert got == sorted(fwd.get(i, [])), i
    assert (tq[~tmask] == 0).all()           # padding entries are masked


def test_linear_edge_count():
    """The headline claim: edges grow linearly in n (not quadratically)."""
    counts = []
    for nb in (16, 32, 64):
        pat = patterns.build_pattern(cfg_of(), nb * 16)
        edges = pat.key_mask.sum()
        counts.append(edges / nb)
    assert max(counts) - min(counts) <= 1.0, "edges-per-block must be O(1)"
