"""Substrate: data determinism, schedules, optimizers, checkpointing,
sharding rules, elastic replan, straggler policy."""
import dataclasses
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional extra — see requirements.txt
    from _prop import given, settings, st

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import optimizers as Opt
from repro.optim import schedules
from repro.ckpt import checkpoint as CKPT
from repro.ft.elastic import plan_mesh, usable_device_count
from repro.ft.straggler import StragglerDetector


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = DataConfig(vocab_size=1000, seq_len=64, batch_size=2, seed=9)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    assert (a["tokens"] == b["tokens"]).all()
    c = SyntheticLM(cfg).batch(6)
    assert (a["tokens"] != c["tokens"]).any()


def test_data_host_sharding_disjoint_streams():
    cfg0 = DataConfig(seq_len=64, batch_size=2, num_hosts=2, host_id=0)
    cfg1 = dataclasses.replace(cfg0, host_id=1)
    a = SyntheticLM(cfg0).batch(0)
    b = SyntheticLM(cfg1).batch(0)
    assert (a["tokens"] != b["tokens"]).any()


def test_mlm_masking():
    cfg = DataConfig(seq_len=128, batch_size=4, mlm=True, mlm_rate=0.15)
    b = SyntheticLM(cfg).batch(0)
    rate = b["loss_mask"].mean()
    assert 0.08 < rate < 0.25
    masked = b["loss_mask"].astype(bool)
    assert (b["tokens"][masked] == cfg.mask_token).mean() > 0.5  # ~80%
    assert (b["tokens"][~masked] == b["labels"][~masked]).all()


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=64, batch_size=2)
    src = SyntheticLM(cfg)
    b = src.batch(3)
    assert b["tokens"].shape == b["labels"].shape == (2, 64)


# --------------------------------------------------------------------------
# schedules / optimizers
# --------------------------------------------------------------------------

def test_wsd_schedule_shape():
    fn = schedules.wsd(1.0, warmup=10, stable=80, total=100)
    assert float(fn(jnp.asarray(0))) < 0.2
    assert abs(float(fn(jnp.asarray(50))) - 1.0) < 1e-6     # stable plateau
    assert float(fn(jnp.asarray(99))) < 0.15                # fast decay tail


def test_cosine_and_linear_monotone_decay():
    for fn in (schedules.cosine(1.0, 10, 100), schedules.linear(1.0, 10, 100)):
        vals = [float(fn(jnp.asarray(s))) for s in range(10, 100, 10)]
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    lr = 0.1 if kind == "adamw" else 0.5
    opt = Opt.by_name(kind, schedules.constant(lr))
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for step in range(60):
        grads = {"w": 2 * params["w"]}            # d/dw |w|^2
        params, state, _ = opt.update(grads, state, params,
                                      jnp.asarray(step))
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = Opt.clip_by_global_norm(g, 1.0)
    assert abs(float(Opt.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_adafactor_state_is_factored():
    opt = Opt.adafactor(schedules.constant(1e-2))
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    st_ = opt.init(params)
    assert st_["s"]["w"]["vr"].shape == (8,)
    assert st_["s"]["w"]["vc"].shape == (16,)
    assert st_["s"]["w"]["m"].dtype == jnp.bfloat16
    assert st_["s"]["b"]["v"].shape == (16,)
    # state_spec mirrors init shapes
    from repro.models.params import P, abstract_params
    spec = opt.state_spec({"w": P((8, 16), ("embed", "mlp")),
                           "b": P((16,), ("embed",))})
    abs_tree = abstract_params(spec)
    assert abs_tree["s"]["w"]["vr"].shape == (8,)
    assert abs_tree["s"]["w"]["vc"].shape == (16,)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(state, d, step=7)
        CKPT.save(state, d, step=9)
        assert CKPT.latest_step(d) == 9
        restored, step = CKPT.restore(d)
        assert step == 9
        np.testing.assert_array_equal(restored["params"]["w"],
                                      np.arange(6.0).reshape(2, 3))


def test_checkpoint_async_then_restore():
    state = {"w": jnp.ones((4, 4))}
    with tempfile.TemporaryDirectory() as d:
        t = CKPT.save_async(state, d, step=1)
        t.join()
        r, s = CKPT.restore(d)
        assert s == 1 and (r["w"] == 1).all()


def test_checkpoint_atomicity_no_partial_dirs():
    state = {"w": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(state, d, step=3)
        import pathlib
        names = [p.name for p in pathlib.Path(d).iterdir()]
        assert names == ["step_000000003"]


# --------------------------------------------------------------------------
# sharding rules engine
# --------------------------------------------------------------------------

def test_sharding_rules_divisibility_fallback():
    from repro.dist import sharding as Sh
    mesh = Sh.abstract_mesh((16, 16), ("data", "model"))
    # vocab divisible -> model
    s = Sh.spec_for((64000, 4096), ("vocab", "embed"), mesh)
    assert s[0] == "model" and s[1] == "data"
    # vocab NOT divisible (92553) -> falls to None, embed -> data
    s = Sh.spec_for((92553, 6144), ("vocab", "embed"), mesh)
    assert s[0] is None and s[1] == "data"
    # kv_heads 8 on model=16 -> replicated
    s = Sh.spec_for((32, 8, 4096, 128), ("batch", "kv_heads", "seq", None), mesh)
    assert s[1] is None
    # batch takes data; seq falls to model
    assert s[0] == "data" and s[2] == "model"
    # unshardable batch (B=2): seq takes everything
    s = Sh.spec_for((2, 8, 4096, 128), ("batch", "kv_heads", "seq", None), mesh)
    assert s[0] is None and s[2] == ("data", "model")


def test_sharding_multi_axis_batch():
    from repro.dist import sharding as Sh
    mesh = Sh.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    s = Sh.spec_for((256, 4096), ("batch", None), mesh)
    assert s[0] == ("pod", "data")
    # batch=1 -> nothing
    s = Sh.spec_for((1, 1), ("batch", None), mesh)
    assert s[0] is None


def test_no_mesh_axis_used_twice():
    from repro.dist import sharding as Sh
    mesh = Sh.abstract_mesh((16, 16), ("data", "model"))
    s = Sh.spec_for((16, 4096, 8192), ("experts", "embed", "mlp"), mesh)
    flat = [a for part in s if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------

def test_elastic_replan_preserves_tp_when_possible():
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    # lose 16 chips -> 240 devices; 240 % 16 == 0 -> keep TP 16
    p = plan_mesh(240, model_parallel=16)
    assert p.shape == (15, 16)
    # 250 % 16 != 0 -> degrade TP to 2 (250 = 125*2)
    p = plan_mesh(250, model_parallel=16)
    assert p.shape[1] in (1, 2) and p.shape[0] * p.shape[1] == 250


def test_elastic_multipod():
    p = plan_mesh(512, model_parallel=16, pods=2)
    assert p.shape == (2, 16, 16) and p.axes == ("pod", "data", "model")
    assert usable_device_count(512, model_parallel=16, pods=2) == 512


@settings(max_examples=25, deadline=None)
@given(n=st.integers(16, 1024))
def test_elastic_replan_always_valid(n):
    p = plan_mesh(n, model_parallel=16)
    used = int(np.prod(p.shape))
    assert used <= n
    assert used >= n // 2 or n < 32      # never waste more than half


def test_straggler_eviction():
    det = StragglerDetector()
    # 8 hosts: host 7 consistently 5x slower
    for step in range(30):
        times = {h: 1.0 + 0.01 * np.random.default_rng(step * 8 + h).random()
                 for h in range(7)}
        times[7] = 5.0
        evict = det.to_evict(times)
    assert 7 in evict
    # healthy hosts never evicted
    assert all(h not in evict for h in range(7))
