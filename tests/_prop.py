"""Property-test shim: re-export `hypothesis` when installed, else a tiny
deterministic fallback so tier-1 collection never hard-fails on the missing
extra (hypothesis is pinned in requirements.txt but optional at runtime).

The fallback runs each property test over a small fixed sample grid instead
of skipping it outright.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

except ImportError:
    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            mid = (lo + hi) // 2
            return [lo, mid, hi]

        @staticmethod
        def sampled_from(values):
            return list(values)

        @staticmethod
        def booleans():
            return [False, True]

    st = _Strategies()

    def settings(**_kwargs):
        return lambda fn: fn

    def given(**strategies):
        names = list(strategies)
        pools = [strategies[n] for n in names]
        cases = max(len(p) for p in pools)

        def deco(fn):
            def wrapper():
                for i in range(cases):
                    fn(**{n: pools[j][i % len(pools[j])]
                          for j, n in enumerate(names)})
            # NOT functools.wraps: pytest must see the zero-arg signature,
            # not the wrapped function's strategy parameters
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
