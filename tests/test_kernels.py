"""Pallas kernel sweeps (interpret mode on CPU) vs pure-jnp oracles.

Per the deliverable: each kernel swept over shapes and dtypes with
assert_allclose against ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional extra — see requirements.txt
    from _prop import given, settings, st

from repro.core import patterns
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _mk(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# --------------------------------------------------------------------------
# BigBird fused attention kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4), (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("B,Hq,Hkv,S,d,b,w,g,r", [
    (1, 2, 1, 256, 16, 16, 3, 2, 2),
    (2, 4, 2, 512, 32, 32, 3, 1, 3),
    (1, 4, 4, 256, 64, 16, 5, 1, 1),
    (2, 2, 2, 384, 16, 16, 3, 2, 0),     # no random
    (1, 8, 2, 256, 16, 16, 1, 0, 2),     # no global (window+random only)
])
def test_bigbird_kernel_sweep(dtype, atol, causal, B, Hq, Hkv, S, d, b, w, g, r):
    if not causal and w % 2 == 0:
        w += 1
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                 num_global_blocks=g, num_random_blocks=r,
                                 causal=causal)
    if g + w + r > S // b:
        pytest.skip("pattern > sequence")
    q, k, v = _mk((B, Hq, S, d), dtype), _mk((B, Hkv, S, d), dtype), \
        _mk((B, Hkv, S, d), dtype)
    out = ops.bigbird_attention_fused(q, k, v, cfg)
    oracle = ref.bigbird_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), cfg)
    np.testing.assert_allclose(out.astype(jnp.float32), oracle,
                               atol=atol, rtol=atol)


def test_bigbird_kernel_matches_blockified_exact_pattern():
    """Kernel and blockified must implement the *same* graph (same seeds)."""
    from repro.core.blockified import bigbird_attention_blockified
    cfg = patterns.BigBirdConfig(block_size=16, num_window_blocks=3,
                                 num_global_blocks=2, num_random_blocks=2,
                                 causal=True, seed=7)
    q, k, v = _mk((1, 2, 256, 16), jnp.float32), \
        _mk((1, 2, 256, 16), jnp.float32), _mk((1, 2, 256, 16), jnp.float32)
    a = ops.bigbird_attention_fused(q, k, v, cfg)
    b = bigbird_attention_blockified(q, k, v, cfg)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# WKV6 recurrence kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-3)])
@pytest.mark.parametrize("B,T,H,D,chunk", [
    (1, 64, 2, 8, 16), (2, 128, 3, 16, 32), (1, 256, 1, 32, 64),
    (2, 96, 2, 16, 32),
])
def test_wkv6_kernel_sweep(dtype, atol, B, T, H, D, chunk):
    if T % chunk != 0:
        pytest.skip("T % chunk")
    r = _mk((B, T, H, D), dtype)
    k = _mk((B, T, H, D), dtype)
    v = _mk((B, T, H, D), dtype)
    w = jnp.asarray(RNG.uniform(0.6, 0.99, (B, T, H, D)), dtype)
    u = _mk((H, D), dtype)
    out = ops.wkv6_scan(r, k, v, w, u, chunk=chunk)
    oracle = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               oracle.astype(jnp.float32), atol=atol, rtol=atol)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 2), H=st.integers(1, 3),
       D=st.sampled_from([8, 16]), nchunk=st.integers(1, 4),
       seed=st.integers(0, 100))
def test_wkv6_property_chunk_invariance(B, H, D, nchunk, seed):
    """Output must not depend on the chunking (state carried correctly)."""
    rng = np.random.default_rng(seed)
    T = 32 * nchunk
    r = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.6, 0.99, (B, T, H, D)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, D)), jnp.float32)
    a = ops.wkv6_scan(r, k, v, w, u, chunk=32)
    b = ops.wkv6_scan(r, k, v, w, u, chunk=T)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# Mamba selective-scan kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,di,st,chunk,dib", [
    (1, 64, 32, 8, 16, 32), (2, 128, 64, 16, 32, 32), (1, 96, 128, 8, 32, 64),
])
def test_mamba_kernel_sweep(B, T, di, st, chunk, dib):
    u = _mk((B, T, di), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, T, di)), jnp.float32)
    bm = _mk((B, T, st), jnp.float32)
    cm = _mk((B, T, st), jnp.float32)
    a = _mk((di, st), jnp.float32) * 0.5
    ds = _mk((di,), jnp.float32)
    out = ops.mamba_scan(u, dt, bm, cm, a, ds, chunk=chunk, di_block=dib)
    oracle = ref.mamba_scan_ref(u, dt, bm, cm, a, ds)
    np.testing.assert_allclose(out, oracle, atol=1e-4, rtol=1e-4)


def test_wkv6_decay_forgets_past():
    """With w ~ 0 the state resets: output depends only on current token."""
    B, T, H, D = 1, 8, 1, 8
    r = _mk((B, T, H, D), jnp.float32)
    k = _mk((B, T, H, D), jnp.float32)
    v = _mk((B, T, H, D), jnp.float32)
    w = jnp.full((B, T, H, D), 1e-6, jnp.float32)
    u = _mk((H, D), jnp.float32)
    out = ops.wkv6_scan(r, k, v, w, u, chunk=8)
    # token t output = r_t . (u k_t v_t) only (state ~ single prev token kv)
    # check: zeroing far-past tokens doesn't change last output
    k2 = k.at[:, :4].set(0.0)
    v2 = v.at[:, :4].set(0.0)
    out2 = ops.wkv6_scan(r, k2, v2, w, u, chunk=8)
    np.testing.assert_allclose(out[:, -1], out2[:, -1], atol=1e-4)


# --------------------------------------------------------------------------
# paged bounded-decode kernel (serving path, forward-only)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 2)])
def test_paged_decode_kernel_matches_gather(Hq, Hkv):
    """Pallas paged-decode kernel vs the XLA two-level-gather baseline in
    models/decode (interpret mode on CPU), over permuted page tables,
    heterogeneous positions and GQA groups."""
    from repro.models import decode as D
    b, max_pages, P, dh, B = 8, 16, 70, 16, 3
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=3,
                                 num_global_blocks=1, num_random_blocks=1,
                                 causal=True, seed=2)
    kc = _mk((P, Hkv, b, dh), jnp.float32)
    vc = _mk((P, Hkv, b, dh), jnp.float32)
    q = _mk((B, Hq, 1, dh), jnp.float32)
    perm = RNG.permutation(np.arange(1, P))[:B * max_pages]
    pt = jnp.asarray(perm.reshape(B, max_pages).astype(np.int32))
    pos = jnp.asarray([7, 66, 127], jnp.int32)    # first/middle/last block
    base = D._bigbird_decode_attn_paged(q, kc, vc, pt, pos, cfg, 0,
                                        impl="gather")
    kern = ops.bigbird_paged_decode_attn(q, kc, vc, pt, pos, cfg, layer=0)
    np.testing.assert_allclose(kern, base, atol=1e-5, rtol=1e-5)


def test_paged_decode_kernel_masks_unwritten_tail():
    """Page-table entries past the allocated region point at the dump page;
    its (garbage) contents must not leak into the output."""
    from repro.models import decode as D
    b, max_pages, P, dh, B, H = 8, 8, 20, 16, 1, 2
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=2,
                                 num_global_blocks=1, num_random_blocks=1,
                                 causal=True)
    kc = _mk((P, H, b, dh), jnp.float32)
    vc = _mk((P, H, b, dh), jnp.float32)
    q = _mk((B, H, 1, dh), jnp.float32)
    pos = jnp.asarray([3 * b + 2], jnp.int32)     # only blocks 0..3 written
    pt = np.zeros((B, max_pages), np.int32)
    pt[0, :4] = [5, 6, 7, 8]
    out1 = ops.bigbird_paged_decode_attn(q, kc, vc, jnp.asarray(pt), pos, cfg)
    kc2 = kc.at[0].add(99.0)                      # poison the dump page
    vc2 = vc.at[0].add(99.0)
    out2 = ops.bigbird_paged_decode_attn(q, kc2, vc2, jnp.asarray(pt), pos,
                                         cfg)
    np.testing.assert_allclose(out1, out2, atol=1e-6)

# --------------------------------------------------------------------------
# ragged prefill kernel (serving path, forward-only)
# --------------------------------------------------------------------------

def _ragged_gather_oracle(q, kc, vc, pt, starts, cfg):
    """Pure-jnp mirror of models/decode._ragged_attn_layer's XLA read."""
    import jax
    from repro.models.decode import _paged_gather
    B, Hq, C, dh = q.shape
    Hkv, b = kc.shape[1], cfg.block_size
    nc, grp = C // b, Hq // kc.shape[1]
    pat = patterns.build_pattern(cfg, pt.shape[1] * b, layer=0)
    idx, msk = jnp.asarray(pat.key_blocks), jnp.asarray(pat.key_mask)
    qb = jnp.asarray(starts)[:, None] // b + jnp.arange(nc)
    rows, rmsk = idx[qb], msk[qb]
    Ls = rows.shape[-1]
    kg = _paged_gather(kc, jnp.asarray(pt), rows.reshape(B, nc * Ls)) \
        .reshape(B, Hkv, nc, Ls * b, dh)
    vg = _paged_gather(vc, jnp.asarray(pt), rows.reshape(B, nc * Ls)) \
        .reshape(B, Hkv, nc, Ls * b, dh)
    flat = (rows[..., None] * b + jnp.arange(b)).reshape(B, nc, Ls * b)
    qpos = (jnp.asarray(starts)[:, None] + jnp.arange(C)).reshape(B, nc, b)
    valid = (jnp.repeat(rmsk, b, axis=-1)[:, :, None, :]
             & (flat[:, :, None, :] <= qpos[..., None]))
    qf = q.reshape(B, Hkv, grp, nc, b, dh)
    s = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qf, kg) / np.sqrt(dh)
    s = jnp.where(valid[:, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgnqk,bhnkd->bhgnqd", pr, vg)
    return o.reshape(B, Hq, C, dh)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 2)])
def test_ragged_prefill_kernel_matches_gather(Hq, Hkv):
    """Pallas ragged-prefill kernel vs the XLA two-level-gather baseline
    (interpret mode on CPU): permuted page tables, per-row chunk offsets,
    GQA groups — each row at a different logical block of its own cache."""
    b, max_pages, P, dh, B, C = 8, 8, 70, 16, 3, 16
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=3,
                                 num_global_blocks=1, num_random_blocks=1,
                                 causal=True, seed=2)
    kc = _mk((P, Hkv, b, dh), jnp.float32)
    vc = _mk((P, Hkv, b, dh), jnp.float32)
    q = _mk((B, Hq, C, dh), jnp.float32)
    perm = RNG.permutation(np.arange(1, P))[:B * max_pages]
    pt = perm.reshape(B, max_pages).astype(np.int32)
    starts = np.asarray([8, 16, 48], np.int32)   # heterogeneous offsets
    base = _ragged_gather_oracle(q, kc, vc, pt, starts, cfg)
    kern = ops.bigbird_ragged_prefill_attn(q, kc, vc, pt, starts, cfg,
                                           layer=0)
    np.testing.assert_allclose(kern, base, atol=1e-5, rtol=1e-5)


def test_ragged_prefill_kernel_rows_independent():
    """A ragged batch must equal each row run alone (B=1): this is the
    property the Engine's bit-identity contract leans on — batching chunks
    of different prompts cannot perturb any single prompt's prefill."""
    b, max_pages, P, dh, Hq, Hkv, C = 8, 8, 40, 16, 4, 2, 16
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=3,
                                 num_global_blocks=1, num_random_blocks=1,
                                 causal=True, seed=5)
    kc = _mk((P, Hkv, b, dh), jnp.float32)
    vc = _mk((P, Hkv, b, dh), jnp.float32)
    q = _mk((3, Hq, C, dh), jnp.float32)
    perm = RNG.permutation(np.arange(1, P))[:3 * max_pages]
    pt = perm.reshape(3, max_pages).astype(np.int32)
    starts = np.asarray([8, 32, 16], np.int32)
    batched = np.asarray(ops.bigbird_ragged_prefill_attn(
        q, kc, vc, pt, starts, cfg, layer=0))
    for i in range(3):
        solo = np.asarray(ops.bigbird_ragged_prefill_attn(
            q[i:i + 1], kc, vc, pt[i:i + 1], starts[i:i + 1], cfg, layer=0))
        np.testing.assert_array_equal(batched[i:i + 1], solo)
