"""Speculative decoding: lossless acceptance over the paged KV pool.

The load-bearing claims (DESIGN.md §Speculative decoding):
  * greedy spec decode is TOKEN-IDENTICAL to vanilla greedy decode for
    every (k, provider), including staggered continuous batching and
    after paged rollback;
  * sampled spec decode draws every emitted token from exactly the
    vanilla sampler's truncated distribution (residual rejection
    sampling — checked both at the unit level against the exact target
    distribution and at the engine level);
  * rollback releases only private speculative pages: refcounts, the
    reservation ledger, and shared prefix pages all survive;
  * tree speculation (N branches, one paged verify forward) keeps all of
    the above: greedy tree spec is token-identical to vanilla, sampled
    tree spec draws from exactly the truncated target distribution even
    when the draft proposes from its own temperature, and path rollback
    preserves the ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec
from repro.models import model as M
from repro.serve import Engine, Request, SamplingSpec, SpecConfig
from repro.serve import sampling as Smp
from repro.serve import spec as Spc

KEY = jax.random.PRNGKey(0)


def _cfg(vocab=128, max_seq=256, kv_heads=4):
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    return M.ModelConfig(name="spec-test", d_model=32, num_layers=2,
                         num_heads=4, num_kv_heads=kv_heads, d_ff=64,
                         vocab_size=vocab, attn=bb, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=max_seq)


@pytest.fixture(scope="module")
def built():
    cfg = _cfg()
    return cfg, M.init(cfg, KEY)


@pytest.fixture(scope="module")
def vanilla_ref(built):
    """Vanilla greedy streams for the standard prompt set (computed once
    — every greedy-identity test diffs against these)."""
    cfg, params = built
    toks, _ = _drain(cfg, params, _reqs(_prompts()))
    return toks


def _prompts(seed=3, lens=(19, 33, 11)):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 128, size=l).astype(np.int32) for l in lens]


def _reqs(prompts, max_new=10, **samp):
    return [Request(prompt=p, max_new_tokens=max_new,
                    sampling=SamplingSpec(seed=i, **samp))
            for i, p in enumerate(prompts)]


def _drain(cfg, params, reqs, **engine_kw):
    eng = Engine(cfg, params, max_len=64, capacity=3, **engine_kw)
    for r in reqs:
        eng.submit(r)
    return [r.tokens for r in eng.drain()], eng


def _pool_ok(pool):
    """Reservation-ledger + refcount invariants after drain."""
    assert pool.pages_in_use == 0
    assert pool.pages_reserved == 0
    assert sum(len(f) for f in pool._free) == \
        pool.num_pages - pool.data_shards
    assert not pool._prefix and not pool._page_key


# --------------------------------------------------------------------------
# greedy: token-identity with vanilla decode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3, 4])
def test_greedy_ngram_spec_identical_to_vanilla(built, vanilla_ref, k):
    cfg, params = built
    ref = vanilla_ref
    got, eng = _drain(cfg, params, _reqs(_prompts()),
                      spec=SpecConfig(k=k, provider="ngram"))
    assert got == ref
    _pool_ok(eng.pool)


def test_greedy_model_draft_same_config_accepts_everything(built, vanilla_ref):
    """Draft == target: every budgeted draft token must be accepted (the
    verify logits are bit-identical to the draft's own decode — this is
    the strongest verify==decode parity check), and the stream still
    equals vanilla."""
    cfg, params = built
    ref = vanilla_ref
    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=3, provider="model",
                                 draft_cfg=cfg, draft_params=params))
    for r in _reqs(_prompts()):
        eng.submit(r)
    results = eng.drain()
    assert [r.tokens for r in results] == ref
    assert all(r.draft_accepted == r.draft_proposed > 0 for r in results)
    assert eng.spec_stats()["accepted_total"] > 0
    _pool_ok(eng.pool)


def test_greedy_model_draft_random_rejections_still_identical(built, vanilla_ref):
    """A random unrelated draft is wrong essentially always — every round
    exercises rejection + paged rollback — and the output must STILL be
    exactly the vanilla stream (losslessness under total draft failure)."""
    cfg, params = built
    dcfg = M.ModelConfig(name="draft", d_model=16, num_layers=1,
                         num_heads=2, num_kv_heads=2, d_ff=32,
                         vocab_size=128, attn=cfg.attn, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=256)
    dparams = M.init(dcfg, jax.random.PRNGKey(7))
    ref = vanilla_ref
    got, eng = _drain(cfg, params, _reqs(_prompts()),
                      spec=SpecConfig(k=3, provider="model",
                                      draft_cfg=dcfg, draft_params=dparams))
    assert got == ref
    _pool_ok(eng.pool)


def test_spec_staggered_admission_matches_vanilla_solo(built):
    """Requests joining a speculating batch mid-flight must produce
    exactly their vanilla solo streams (per-slot draft state, acceptance
    RNG, and rollback are all co-resident-independent)."""
    cfg, params = built
    prompts = _prompts(seed=5)
    solo = []
    for r in _reqs(prompts):
        eng = Engine(cfg, params, max_len=64, capacity=3)
        eng.submit(r)
        solo.append(eng.drain()[0].tokens)

    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=3))
    rs = _reqs(prompts)
    eng.submit(rs[0])
    eng.step(); eng.step()
    eng.submit(rs[1])
    eng.step()
    eng.submit(rs[2])
    results = eng.drain()
    assert [r.request_id for r in results] == [0, 1, 2]
    for r, expect in zip(results, solo):
        assert r.tokens == expect, r.request_id
    _pool_ok(eng.pool)


def test_spec_stop_token_inside_accepted_window(built):
    """A stop token accepted mid-window must truncate the emission at it
    (tokens after the stop are discarded) and finish with reason 'stop'."""
    cfg, params = built
    prompt = _prompts(seed=9, lens=(16,))[0]
    free, _ = _drain(cfg, params,
                     [Request(prompt=prompt, max_new_tokens=8,
                              sampling=SamplingSpec(seed=0))])
    stop = free[0][3]                  # 4th greedy token as "EOS"
    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=4, provider="model",
                                 draft_cfg=cfg, draft_params=params))
    eng.submit(Request(prompt=prompt, max_new_tokens=8, stop_token=stop,
                       sampling=SamplingSpec(seed=0)))
    res = eng.drain()[0]
    assert res.finish_reason == "stop"
    assert res.tokens == free[0][:4]
    _pool_ok(eng.pool)


# --------------------------------------------------------------------------
# paged rollback: refcounts, reservations, shared prefix pages
# --------------------------------------------------------------------------

def _step_invariants(pool):
    """Mid-flight ledger invariants: mapped pages are refcounted and
    disjoint from the free list; reservations match the per-slot sums."""
    free = [pg for f in pool._free for pg in f]
    assert len(set(free)) == len(free)
    for d in range(pool.data_shards):
        assert pool._reserved[d] == sum(
            s.reserved for i, s in enumerate(pool.slots)
            if s is not None and pool.slot_shard(i) == d)
        assert len(pool._free[d]) >= pool._reserved[d]
    for s in (s for s in pool.slots if s is not None):
        for pg in s.pages:
            assert pool.refcount[pg] >= 1
            assert pg not in free


def test_spec_rollback_ledger_invariants_every_step(built):
    cfg, params = built
    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=4))
    for r in _reqs(_prompts(), max_new=12):
        eng.submit(r)
    while eng._queue or eng.pool.active_slots():
        eng.step()
        _step_invariants(eng.pool)
    _pool_ok(eng.pool)


def test_spec_shared_prefix_pages_survive_rollback(built):
    """Speculation must never release a shared prefix page: co-residents
    with a common one-page prefix keep sharing it through draft/verify
    rounds, streams equal vanilla, refcount lifecycle intact."""
    cfg, params = built
    rng = np.random.default_rng(4)
    prefix = rng.integers(4, 128, size=8).astype(np.int32)   # one page
    prompts = [np.concatenate([prefix,
                               rng.integers(4, 128, size=n).astype(np.int32)])
               for n in (20, 24)]
    reqs = lambda: [Request(prompt=p, max_new_tokens=10,
                            sampling=SamplingSpec(seed=i))
                    for i, p in enumerate(prompts)]
    ref, _ = _drain(cfg, params, reqs())
    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=3))
    r0, r1 = reqs()
    eng.submit(r0)
    eng.step(); eng.step()             # req0 resident, prefix indexed
    eng.submit(r1)
    saw_share = False
    results = {}
    while eng._queue or eng.pool.active_slots():
        for r in eng.step():
            results[r.request_id] = r
        _step_invariants(eng.pool)
        s1 = eng.pool.slots[1]
        if s1 is not None and s1.shared_pages \
                and eng.pool.slots[0] is not None:
            saw_share = True           # both sharers resident
            assert eng.pool.refcount[s1.pages[0]] == 2
    assert saw_share and eng.pool.prefix_hits == 1
    assert [results[i].tokens for i in range(2)] == ref
    _pool_ok(eng.pool)


def test_pool_rollback_unmaps_only_past_keep(built):
    """Direct pool-level check: rollback returns exactly the pages past
    keep_blocks to the free list and re-credits the reservation."""
    cfg, params = built
    eng = Engine(cfg, params, max_len=64, capacity=3)
    prompt = _prompts(seed=11, lens=(12,))[0]
    eng.submit(Request(prompt=prompt, max_new_tokens=16,
                       sampling=SamplingSpec(seed=0)))
    eng.step()
    pool, s = eng.pool, eng.pool.slots[0]
    need = pool.pages_needed(12, 16)
    mapped0, reserved0 = len(s.pages), s.reserved
    assert mapped0 + reserved0 == need
    pool.ensure_capacity(0, need - 1)        # map everything
    assert len(s.pages) == need and s.reserved == 0
    pool.rollback(0, mapped0)                # back to the prompt mapping
    assert len(s.pages) == mapped0 and s.reserved == reserved0
    assert pool._reserved[0] == reserved0
    assert all(int(p) == pool.dump_page(0)
               for p in pool.page_tables[0, mapped0:])


# --------------------------------------------------------------------------
# sampled: residual rejection is lossless
# --------------------------------------------------------------------------

def test_accept_emits_exactly_the_truncated_target_distribution():
    """Monte-carlo the acceptance rule on fixed logits: whatever the
    draft proposes, the first emitted token's distribution must equal the
    truncated target distribution (the residual-sampling identity)."""
    rng_l = np.random.default_rng(0)
    logits = rng_l.standard_normal((2, 50)).astype(np.float32) * 2.0
    samp = SamplingSpec(temperature=0.8, top_k=10, top_p=0.9, seed=0)
    p = Smp.truncated_probs(logits[0], samp)
    N = 40000
    for d in (int(np.argmax(p)), int(np.argsort(-p)[3]), 0):
        rng = np.random.default_rng(1234 + d)
        counts = np.zeros(50)
        for _ in range(N):
            emitted, _ = Spc.accept(logits, np.asarray([d]), samp, rng)
            counts[emitted[0]] += 1
        tv = 0.5 * np.abs(counts / N - p).sum()
        assert tv < 0.02, (d, tv)


def test_sampled_spec_engine_marginals_match_vanilla():
    """Engine-level seeded statistical check: per-position marginal token
    distributions of the spec engine equal the vanilla engine's.  A
    vocab-12 model keeps the support small enough for N=200 seeds to be
    conclusive (the tight per-token check is the unit-level MC above)."""
    cfg = _cfg(vocab=12)
    params = M.init(cfg, KEY)
    prompt = np.random.default_rng(21).integers(
        4, 12, size=24).astype(np.int32)
    N, T = 200, 3

    def streams(spec):
        out = []
        eng = Engine(cfg, params, max_len=64, capacity=1, spec=spec)
        for s in range(N):
            eng.submit(Request(
                prompt=prompt, max_new_tokens=T,
                sampling=SamplingSpec(temperature=1.0, seed=s)))
            out.append(eng.drain()[0].tokens)
        return np.asarray(out)

    a, b = streams(None), streams(SpecConfig(k=2))
    # same seeds, token 0 comes from the same prefill sampler: identical
    np.testing.assert_array_equal(a[:, 0], b[:, 0])
    for t in range(1, T):
        ca = np.bincount(a[:, t], minlength=cfg.vocab_size) / N
        cb = np.bincount(b[:, t], minlength=cfg.vocab_size) / N
        assert 0.5 * np.abs(ca - cb).sum() < 0.2, t


# --------------------------------------------------------------------------
# providers
# --------------------------------------------------------------------------

def test_ngram_draft_proposes_continuation_of_repeated_ngram():
    d = Spc.NGramDraft(k=4, max_n=3, min_n=1)
    d.admit(0, np.asarray([5, 6, 7, 9, 9, 5, 6, 7, 3, 1], np.int32))
    d.observe(0, [5, 6, 7])            # history now ends with 5 6 7
    drafts, lens = d.propose([0], np.asarray([7] * 1, np.int32),
                             np.asarray([4], np.int32))
    # longest suffix match is [5,6,7] at position 5 -> continue 3, 1, ...
    assert lens[0] >= 2
    assert drafts[0, :2].tolist() == [3, 1]


def test_ngram_draft_no_match_proposes_nothing():
    d = Spc.NGramDraft(k=4)
    d.admit(0, np.arange(4, 24, dtype=np.int32))   # all tokens distinct
    drafts, lens = d.propose([0], np.asarray([99], np.int32),
                             np.asarray([4], np.int32))
    assert lens[0] == 0


def test_spec_requires_attention_only_causal_lm(built):
    cfg, params = built
    import dataclasses
    bad = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, causal=False))
    with pytest.raises(ValueError, match="causal"):
        Engine(bad, M.init(bad, KEY), max_len=64, capacity=2,
               prefill_chunk=None, spec=SpecConfig(k=2))


# --------------------------------------------------------------------------
# tree speculation: one verify forward scores N branches
# --------------------------------------------------------------------------

def test_tree_topology_caterpillar_structure():
    topo = Spc.tree_topology((3, 2, 1))
    assert topo.size == 7 and topo.depth == 3
    assert topo.depths.tolist() == [0, 1, 1, 1, 2, 2, 3]
    assert topo.parent.tolist() == [-1, 0, 0, 0, 1, 1, 4]
    # the spine (first node of each depth group) carries the linear draft
    assert topo.spine.tolist() == [0, 1, 4, 6]
    assert topo.children[0][0] == 1 and topo.children[1][0] == 4
    # anc[u, d] = u's ancestor at depth d (the verify-mask gather table)
    assert topo.anc[6].tolist() == [0, 1, 4, 6]
    assert topo.anc[5].tolist()[:3] == [0, 1, 5]


def test_tree_greedy_walk_accepts_offspine_branch():
    """The greedy walk must descend into a non-spine sibling when the
    target argmax says so, and stop at its leaf with a bonus token."""
    topo = Spc.tree_topology((2, 1))
    tokens = np.zeros(topo.size, np.int64)
    tokens[1], tokens[2], tokens[3] = 5, 9, 7     # spine, sibling, child
    logits = np.full((topo.size, 16), -1.0, np.float32)
    logits[0, 9] = 1.0          # root context: argmax = sibling's token
    logits[2, 11] = 1.0         # sibling context: bonus token 11
    out, m, fin = Spc.accept_tree_greedy(
        np.argmax(logits, -1), tokens, topo, budget=2)
    assert out == [9, 11] and m == 1 and fin == 2


@pytest.mark.parametrize("fanout", [(2, 2, 1), (1, 1, 1)])
def test_greedy_tree_spec_identical_to_vanilla(built, vanilla_ref, fanout):
    """Greedy tree speculation is token-identical to vanilla decode for
    branching and degenerate (linear) fanouts; draft == target means the
    spine is the target argmax chain, so every budgeted depth accepts."""
    cfg, params = built
    got, eng = _drain(cfg, params, _reqs(_prompts()),
                      spec=SpecConfig(k=len(fanout), provider="tree",
                                      draft_cfg=cfg, draft_params=params,
                                      fanout=fanout))
    assert got == vanilla_ref
    assert eng.spec_stats()["accepted_total"] > 0
    _pool_ok(eng.pool)


def test_greedy_tree_random_draft_rejections_still_identical(built,
                                                             vanilla_ref):
    """A random unrelated draft tree is wrong essentially always — every
    round exercises path rollback (unmapping all but the accepted root) —
    and the stream must STILL equal vanilla."""
    cfg, params = built
    dcfg = M.ModelConfig(name="draft", d_model=16, num_layers=1,
                         num_heads=2, num_kv_heads=2, d_ff=32,
                         vocab_size=128, attn=cfg.attn, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=256)
    dparams = M.init(dcfg, jax.random.PRNGKey(7))
    got, eng = _drain(cfg, params, _reqs(_prompts()),
                      spec=SpecConfig(k=2, provider="tree",
                                      draft_cfg=dcfg, draft_params=dparams,
                                      fanout=(2, 2)))
    assert got == vanilla_ref
    _pool_ok(eng.pool)


def test_tree_stop_token_inside_accepted_path(built):
    cfg, params = built
    prompt = _prompts(seed=9, lens=(16,))[0]
    free, _ = _drain(cfg, params,
                     [Request(prompt=prompt, max_new_tokens=8,
                              sampling=SamplingSpec(seed=0))])
    stop = free[0][3]                  # 4th greedy token as "EOS"
    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=4, provider="tree",
                                 draft_cfg=cfg, draft_params=params))
    eng.submit(Request(prompt=prompt, max_new_tokens=8, stop_token=stop,
                       sampling=SamplingSpec(seed=0)))
    res = eng.drain()[0]
    assert res.finish_reason == "stop"
    assert res.tokens == free[0][:4]
    _pool_ok(eng.pool)


def test_tree_spec_int8_identical_to_int8_vanilla(built):
    """Tree verify writes nothing; commit_window's int8 path quantizes
    only the accepted root-to-leaf tokens — so int8 tree spec must equal
    int8 vanilla decode exactly (same quantized cache trajectory)."""
    cfg, params = built
    ref, _ = _drain(cfg, params, _reqs(_prompts()), kv_dtype="int8")
    got, eng = _drain(cfg, params, _reqs(_prompts()), kv_dtype="int8",
                      spec=SpecConfig(k=3, provider="tree", draft_cfg=cfg,
                                      draft_params=params, fanout=(2, 2, 1)))
    assert got == ref
    _pool_ok(eng.pool)


def test_tree_rollback_ledger_invariants_every_step(built):
    cfg, params = built
    eng = Engine(cfg, params, max_len=64, capacity=3,
                 spec=SpecConfig(k=3, provider="tree", draft_cfg=cfg,
                                 draft_params=params, fanout=(2, 1, 1)))
    for r in _reqs(_prompts(), max_new=12):
        eng.submit(r)
    while eng._queue or eng.pool.active_slots():
        eng.step()
        _step_invariants(eng.pool)
    _pool_ok(eng.pool)


def test_tree_accept_emits_exactly_the_truncated_target_distribution():
    """Monte-carlo the TREE acceptance rule: with the spine drawn from the
    draft's own truncated distribution (draft_q) and siblings as point
    masses, the first emitted token's marginal must equal the truncated
    TARGET distribution — the per-depth residual-sampling identity that
    makes sampled tree drafting lossless."""
    topo = Spc.tree_topology((2, 2))
    rng_l = np.random.default_rng(0)
    logits = rng_l.standard_normal((topo.size, 50)).astype(np.float32) * 2.0
    samp = SamplingSpec(temperature=0.8, top_k=10, top_p=0.9, seed=0)
    p = Smp.truncated_probs(logits[0], samp)
    dspec = SamplingSpec(temperature=1.2, top_k=20, seed=0)
    dlog = rng_l.standard_normal((topo.depth, 50)).astype(np.float32) * 2.0
    draft_q = np.stack([Smp.truncated_probs(dlog[d], dspec)
                        for d in range(topo.depth)])
    sibling = [int(np.argsort(-draft_q[d])[1]) for d in range(topo.depth)]
    N = 40000
    draft_rng = np.random.default_rng(5)
    rng = np.random.default_rng(1234)
    counts = np.zeros(50)
    for _ in range(N):
        tokens = np.zeros(topo.size, np.int64)
        for d in range(1, topo.depth + 1):
            grp = topo.children[topo.spine[d - 1]]
            tokens[grp[0]] = draft_rng.choice(50, p=draft_q[d - 1])
            for c in grp[1:]:
                tokens[c] = sibling[d - 1]
        emitted, _, _ = Spc.accept_tree(logits, tokens, topo, topo.depth,
                                        samp, rng, draft_q=draft_q)
        counts[emitted[0]] += 1
    tv = 0.5 * np.abs(counts / N - p).sum()
    assert tv < 0.02, tv


def test_sampled_tree_spec_engine_marginals_match_vanilla():
    """Engine-level seeded statistical check for sampled TREE speculation
    with the draft proposing from its own temperature: per-position
    marginals equal the vanilla engine's (cf. the linear-spec version of
    this test above)."""
    cfg = _cfg(vocab=12)
    params = M.init(cfg, KEY)
    dcfg = M.ModelConfig(name="draft", d_model=16, num_layers=1,
                         num_heads=2, num_kv_heads=2, d_ff=32,
                         vocab_size=12, attn=cfg.attn, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=256)
    dparams = M.init(dcfg, jax.random.PRNGKey(7))
    prompt = np.random.default_rng(21).integers(
        4, 12, size=24).astype(np.int32)
    N, T = 200, 3

    def streams(spec):
        out = []
        eng = Engine(cfg, params, max_len=64, capacity=1, spec=spec)
        for s in range(N):
            eng.submit(Request(
                prompt=prompt, max_new_tokens=T,
                sampling=SamplingSpec(temperature=1.0, seed=s)))
            out.append(eng.drain()[0].tokens)
        return np.asarray(out)

    a = streams(None)
    b = streams(SpecConfig(k=2, provider="tree", draft_cfg=dcfg,
                           draft_params=dparams, fanout=(2, 2),
                           draft_temperature=1.0))
    np.testing.assert_array_equal(a[:, 0], b[:, 0])
    for t in range(1, T):
        ca = np.bincount(a[:, t], minlength=cfg.vocab_size) / N
        cb = np.bincount(b[:, t], minlength=cfg.vocab_size) / N
        assert 0.5 * np.abs(ca - cb).sum() < 0.2, t


# --------------------------------------------------------------------------
# mesh composition
# --------------------------------------------------------------------------

@pytest.mark.multidevice
def test_spec_on_mesh_bit_identical_to_vanilla(built):
    """Replicated verification over the data axis: the spec engine on a
    (2, 2) mesh emits exactly the vanilla (unsharded, unspeculated)
    streams."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices; run with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.serve import mesh as Mx
    cfg = _cfg(kv_heads=2)
    params = M.init(cfg, KEY)
    prompts = _prompts(seed=3, lens=(19, 33, 11, 26))
    reqs = lambda: [Request(prompt=p, max_new_tokens=8,
                            sampling=SamplingSpec(seed=i))
                    for i, p in enumerate(prompts)]
    ref = []
    eng = Engine(cfg, params, max_len=64, capacity=4)
    for r in reqs():
        eng.submit(r)
    ref = [r.tokens for r in eng.drain()]
    eng = Engine(cfg, params, max_len=64, capacity=4,
                 mesh=Mx.make_mesh(2, 2), spec=SpecConfig(k=3))
    for r in reqs():
        eng.submit(r)
    got = [r.tokens for r in eng.drain()]
    assert got == ref
    _pool_ok(eng.pool)


@pytest.mark.multidevice
def test_tree_spec_on_mesh_bit_identical_to_vanilla(built):
    """Tree verification over a (2, 2) mesh: window K/V capture and the
    path commit are per-shard (heads on the model axis, slots on data),
    and the streams must equal the unsharded, unspeculated engine's."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices; run with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.serve import mesh as Mx
    cfg = _cfg(kv_heads=2)
    params = M.init(cfg, KEY)
    prompts = _prompts(seed=3, lens=(19, 33, 11, 26))
    reqs = lambda: [Request(prompt=p, max_new_tokens=8,
                            sampling=SamplingSpec(seed=i))
                    for i, p in enumerate(prompts)]
    eng = Engine(cfg, params, max_len=64, capacity=4)
    for r in reqs():
        eng.submit(r)
    ref = [r.tokens for r in eng.drain()]
    eng = Engine(cfg, params, max_len=64, capacity=4,
                 mesh=Mx.make_mesh(2, 2),
                 spec=SpecConfig(k=3, provider="tree", draft_cfg=cfg,
                                 draft_params=params, fanout=(2, 2, 1)))
    for r in reqs():
        eng.submit(r)
    got = [r.tokens for r in eng.drain()]
    assert got == ref
    _pool_ok(eng.pool)
