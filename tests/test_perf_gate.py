"""CI perf gate (benchmarks/perf_gate.py): band edges and the new
swap/int8 gates, exercised as pure dict-in/violations-out unit tests —
the gate's acceptance bands are load-bearing CI policy, so their edge
behavior is pinned here rather than discovered in a red build."""
import copy
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import perf_gate as PG  # noqa: E402

BAND = 4.0


def _base(**over):
    d = {
        "kv_bytes_per_request_paged": 573440,
        "page_size": 32,
        "max_concurrency_paged": 7,
        "kv_reduction": 0.4531,
        "ttft_s": 0.1,
        "decode_tok_s": 300.0,
        "continuous_tok_s": 330.0,
    }
    d.update(over)
    return d


def _ok(fresh, base):
    return PG.check(fresh, base, BAND)


def test_identical_passes():
    b = _base()
    assert _ok(copy.deepcopy(b), b) == []


def test_kv_growth_band_edges():
    b = _base()
    f = _base(kv_bytes_per_request_paged=int(573440 * 1.009))
    assert _ok(f, b) == []                      # just inside 1%
    f = _base(kv_bytes_per_request_paged=int(573440 * 1.02))
    assert any("kv_bytes_per_request_paged" in v for v in _ok(f, b))


def test_structural_exact_fields_gate_hard():
    for key, val in (("page_size", 16), ("max_concurrency_paged", 6),
                     ("kv_reduction", 0.3)):
        f = _base(**{key: val})
        assert any(key in v for v in _ok(f, _base())), key


def test_timing_band_is_wide_not_vacuous():
    b = _base()
    assert _ok(_base(continuous_tok_s=330.0 / BAND + 0.1), b) == []
    assert any("continuous_tok_s" in v
               for v in _ok(_base(continuous_tok_s=330.0 / BAND - 5), b))


# ---- spec acceptance floor: max(b - 0.15, 0.5*b) -------------------------

def _spec(base_rate, fresh_rate):
    b = _base(spec_acceptance_rate=base_rate, spec_outputs_match=True,
              spec_continuous_tok_s=400.0)
    f = _base(spec_acceptance_rate=fresh_rate, spec_outputs_match=True,
              spec_continuous_tok_s=400.0)
    return _ok(f, b)


def test_acceptance_floor_small_baseline_uses_relative_arm():
    """base 0.0831: absolute arm gives -0.0669 (vacuous); the relative
    arm 0.5*0.0831 = 0.04155 is the binding floor."""
    assert _spec(0.0831, 0.0416) == []
    bad = _spec(0.0831, 0.0415 - 1e-5)
    assert any("spec_acceptance_rate dropped" in v for v in bad)


def test_acceptance_floor_large_baseline_uses_absolute_arm():
    """base 0.5: floor = max(0.35, 0.25) = 0.35 — the absolute arm."""
    assert _spec(0.5, 0.351) == []
    assert any("spec_acceptance_rate dropped" in v for v in _spec(0.5, 0.349))


def test_trained_draft_floor_is_absolute_not_banded():
    """spec_provider tree/model: the hard 0.35 floor replaces the loose
    band.  A drop from 0.6 to 0.36 passes (the band's 0.45 floor would
    have failed it) but 0.34 fails, wherever the baseline sat."""
    b = _base(spec_acceptance_rate=0.6, spec_outputs_match=True,
              spec_continuous_tok_s=900.0, spec_provider="tree")
    f = copy.deepcopy(b)
    f["spec_acceptance_rate"] = 0.36
    assert _ok(f, b) == []
    f["spec_acceptance_rate"] = 0.34
    assert any("trained-draft" in v for v in _ok(f, b))


def test_trained_draft_floor_binds_even_with_low_baseline():
    """The floor is absolute: a trained draft under 0.35 fails even when
    the committed baseline was itself low (the banded formula would have
    passed it — exactly the vacuous-gate hole this floor closes).  The
    same numbers under the ngram provider stay inside the loose band."""
    for prov in ("model", "tree"):
        b = _base(spec_acceptance_rate=0.2, spec_outputs_match=True,
                  spec_continuous_tok_s=900.0, spec_provider=prov)
        f = copy.deepcopy(b)
        f["spec_acceptance_rate"] = 0.21
        assert any("trained-draft" in v for v in _ok(f, b)), prov
    b = _base(spec_acceptance_rate=0.2, spec_outputs_match=True,
              spec_continuous_tok_s=900.0, spec_provider="ngram")
    f = copy.deepcopy(b)
    f["spec_acceptance_rate"] = 0.21
    assert _ok(f, b) == []


def test_spec_outputs_match_gates_hard():
    b = _base(spec_acceptance_rate=0.1, spec_outputs_match=True,
              spec_continuous_tok_s=400.0)
    f = _base(spec_acceptance_rate=0.1, spec_outputs_match=False,
              spec_continuous_tok_s=400.0)
    assert any("spec_outputs_match" in v for v in _ok(f, b))


def test_spec_fields_missing_from_fresh_run_fails():
    b = _base(spec_acceptance_rate=0.1, spec_outputs_match=True,
              spec_continuous_tok_s=400.0)
    assert any("spec metrics missing" in v for v in _ok(_base(), b))


# ---- host-swap gates -----------------------------------------------------

def _swap(**over):
    d = _base(swap_outputs_match=True, swap_out_total=4)
    d.update(over)
    return d


def test_swap_digest_gates_hard():
    assert _ok(_swap(), _swap()) == []
    bad = _ok(_swap(swap_outputs_match=False), _swap())
    assert any("swap_outputs_match" in v for v in bad)


def test_swap_must_actually_run():
    """swap_out_total == 0 means the digest equality proved nothing."""
    bad = _ok(_swap(swap_out_total=0), _swap())
    assert any("swap_out_total is 0" in v for v in bad)


def test_swap_gates_inactive_without_baseline_fields():
    assert _ok(_base(), _base()) == []


# ---- int8 KV gates -------------------------------------------------------

def _int8(**over):
    d = _base(int8_nll_delta=0.001, kv_bytes_per_request_int8=160000,
              max_concurrency_int8=20)
    d.update(over)
    return d


def test_int8_nll_ceiling_uses_absolute_floor_for_tiny_baselines():
    """baseline delta 0.001 -> ceiling max(0.1, 0.002) = 0.1."""
    assert _ok(_int8(int8_nll_delta=0.09), _int8()) == []
    bad = _ok(_int8(int8_nll_delta=0.11), _int8())
    assert any("int8_nll_delta rose" in v for v in bad)


def test_int8_nll_ceiling_scales_with_large_baselines():
    """baseline 0.2 -> ceiling 0.4: relative arm takes over."""
    b = _int8(int8_nll_delta=0.2)
    assert _ok(_int8(int8_nll_delta=0.39), b) == []
    assert any("int8_nll_delta rose" in v
               for v in _ok(_int8(int8_nll_delta=0.41), b))


def test_int8_kv_bytes_growth_gates_hard():
    bad = _ok(_int8(kv_bytes_per_request_int8=int(160000 * 1.02)), _int8())
    assert any("kv_bytes_per_request_int8 grew" in v for v in bad)


def test_int8_concurrency_exact_and_above_paged():
    bad = _ok(_int8(max_concurrency_int8=19), _int8())
    assert any("max_concurrency_int8 changed" in v for v in bad)
    # equal to paged: the compressed pool buys nothing -> gate
    b = _int8(max_concurrency_int8=7)
    bad = _ok(_int8(max_concurrency_int8=7), b)
    assert any("does not exceed" in v for v in bad)


def test_int8_acceptance_floor_matches_f32_formula():
    b = _int8(spec_acceptance_rate_int8=0.0831)
    assert _ok(_int8(spec_acceptance_rate_int8=0.0416), b) == []
    bad = _ok(_int8(spec_acceptance_rate_int8=0.041), b)
    assert any("spec_acceptance_rate_int8 dropped" in v for v in bad)


# ---- metrics-overhead gate ----------------------------------------------

def _obs(**over):
    d = _base(continuous_tok_s_metrics_on=320.0,
              continuous_tok_s_metrics_off=325.0)
    d.update(over)
    return d


def test_metrics_overhead_band_edges():
    """Fresh-vs-fresh: on >= off * 0.97, independent of the baseline's
    own on/off numbers (the baseline only arms the gate)."""
    f = _obs(continuous_tok_s_metrics_on=97.1,
             continuous_tok_s_metrics_off=100.0)
    assert _ok(f, _obs()) == []                 # just inside 3%
    f = _obs(continuous_tok_s_metrics_on=96.9,
             continuous_tok_s_metrics_off=100.0)
    assert any("metrics overhead" in v for v in _ok(f, _obs()))


def test_metrics_overhead_fields_missing_from_fresh_fails():
    bad = _ok(_base(), _obs())
    assert any("metrics overhead arms missing" in v for v in bad)


def test_metrics_overhead_inactive_without_baseline_field():
    f = _base(continuous_tok_s_metrics_on=50.0,
              continuous_tok_s_metrics_off=100.0)
    assert _ok(f, _base()) == []


def test_parse_serving_json_prefers_marker_line():
    text = 'noise\nSERVING_JSON {"a": 1}\nmore'
    assert PG.parse_serving_json(text) == {"a": 1}
    assert PG.parse_serving_json('{"b": 2}') == {"b": 2}
