"""Attention implementation equivalences against the dense-mask oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns
from repro.core.attention import AttentionSpec, attention
from repro.core.blockified import bigbird_attention_blockified
from repro.core.chunked_full import chunked_full_attention
from repro.core.ref_attention import (bigbird_attention_reference,
                                      full_attention_reference)

RNG = np.random.default_rng(0)


def qkv(B=2, Hq=4, Hkv=2, S=256, d=16, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((B, Hq, S, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("S,b,w,g,r", [
    (256, 16, 3, 2, 2), (512, 32, 3, 1, 3), (192, 16, 5, 0, 1),
    (256, 64, 3, 0, 0),
])
def test_blockified_matches_oracle(causal, S, b, w, g, r):
    if not causal and w % 2 == 0:
        w += 1
    cfg = patterns.BigBirdConfig(block_size=b, num_window_blocks=w,
                                 num_global_blocks=g, num_random_blocks=r,
                                 causal=causal)
    if g + w + r > S // b:
        pytest.skip("pattern larger than sequence")
    q, k, v = qkv(S=S)
    ref = bigbird_attention_reference(q, k, v, cfg)
    out = bigbird_attention_blockified(q, k, v, cfg)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("qc,kc", [(64, 64), (128, 256), (256, 64)])
def test_chunked_full_matches_oracle(causal, qc, kc):
    q, k, v = qkv(S=256)
    ref = full_attention_reference(q, k, v, causal=causal)
    out = chunked_full_attention(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_cross_attention_different_lengths():
    q, _, _ = qkv(S=128)
    _, k, v = qkv(S=256)
    ref = full_attention_reference(q, k, v, causal=False)
    out = chunked_full_attention(q, k, v, causal=False, q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_window_spec_equals_bigbird_window_only():
    q, k, v = qkv(S=512)
    spec = AttentionSpec(kind="window", causal=True, block_size=32,
                         window_tokens=96)
    out = attention(q, k, v, spec)
    ref = bigbird_attention_reference(q, k, v, spec.bigbird_config(512))
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_window_attention_is_local():
    """A distant key perturbation must not change window-attention output."""
    q, k, v = qkv(S=512, Hq=2, Hkv=2)
    spec = AttentionSpec(kind="window", causal=True, block_size=32,
                         window_tokens=64)
    base = attention(q, k, v, spec)
    k2 = k.at[:, :, 0:16].add(10.0)           # far from position 511
    v2 = v.at[:, :, 0:16].add(10.0)
    pert = attention(q, k2, v2, spec)
    # last query position is > window away from perturbed keys
    np.testing.assert_allclose(base[:, :, -1], pert[:, :, -1], atol=1e-5)
    # but an early position IS affected
    assert float(jnp.abs(base[:, :, 20] - pert[:, :, 20]).max()) > 1e-3


def test_bigbird_global_token_sees_everything():
    """Perturbing ANY key must change global-token outputs (star graph)."""
    cfg = patterns.BigBirdConfig(block_size=16, num_window_blocks=3,
                                 num_global_blocks=1, num_random_blocks=0)
    q, k, v = qkv(S=256, Hq=2, Hkv=2)
    base = bigbird_attention_blockified(q, k, v, cfg)
    k2 = k.at[:, :, 200].add(5.0)
    v2 = v.at[:, :, 200].add(5.0)
    pert = bigbird_attention_blockified(q, k2, v2, cfg)
    assert float(jnp.abs(base[:, :, 0] - pert[:, :, 0]).max()) > 1e-4


def test_degenerate_small_sequence_falls_back_to_full():
    q, k, v = qkv(S=64)
    spec = AttentionSpec(kind="bigbird", causal=True, block_size=16,
                         num_window_blocks=3, num_global_blocks=2,
                         num_random_blocks=3)
    out = attention(q, k, v, spec)     # 4 blocks < 8 slots -> full fallback
    ref = full_attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_bf16_path_no_nan():
    q, k, v = qkv(S=256, dtype=jnp.bfloat16)
    cfg = patterns.BigBirdConfig(block_size=16, num_window_blocks=3,
                                 num_global_blocks=1, num_random_blocks=1)
    out = bigbird_attention_blockified(q, k, v, cfg)
    assert out.dtype == jnp.bfloat16
    assert not bool(jnp.isnan(out.astype(jnp.float32)).any())
    ref = bigbird_attention_reference(q.astype(jnp.float32),
                                      k.astype(jnp.float32),
                                      v.astype(jnp.float32), cfg)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=3e-2)
