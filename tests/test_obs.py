"""Observability layer (repro/obs/): registry semantics, Prometheus text
rendering, trace ring + Chrome export, per-request timeline completeness
over a staggered continuous-batching run, the injectable clock, and the
instrumentation-changes-nothing digest contract.

Registry/trace state is process-global, so every test that touches the
global REGISTRY / TRACE / clock restores it in a finally block.
"""
import json
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec
from repro.models import model as M
from repro.obs import FakeClock, metrics as Om, set_clock, trace as Otr
from repro.obs.server import MetricsServer
from repro.serve import Engine, Request, SamplingSpec

KEY = jax.random.PRNGKey(0)


# ---- metrics registry ----------------------------------------------------

def test_counter_semantics():
    reg = Om.Registry()
    c = reg.counter("hits_total", "hits")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    c.inc(2, shard="a")
    c.inc(3, shard="b")
    assert c.value(shard="a") == 2 and c.value(shard="b") == 3
    assert c.value(shard="unseen") == 0.0
    assert reg.counter("hits_total") is c          # get-or-create
    with pytest.raises(AssertionError):
        reg.gauge("hits_total")                    # kind mismatch


def test_gauge_set_dec():
    reg = Om.Registry()
    g = reg.gauge("level")
    g.set(7)
    g.dec(2)
    assert g.value() == 5.0
    g.set(-1.5)
    assert g.value() == -1.5


def test_registry_disable_is_noop_and_reset_keeps_registrations():
    reg = Om.Registry()
    c = reg.counter("n_total")
    reg.enabled = False
    c.inc(10)
    assert c.value() == 0.0
    reg.enabled = True
    c.inc(1)
    reg.reset()
    assert c.value() == 0.0
    assert reg.get("n_total") is c


def test_histogram_bucket_edges_le_semantics():
    """Prometheus le: a value exactly at a bound lands IN that bucket;
    values past the last bound count only toward +Inf."""
    reg = Om.Registry()
    h = reg.histogram("lat", buckets=(0.001, 0.01, 0.1, 1.0))
    h.observe(0.001)       # == first bound -> bucket le=0.001
    h.observe(0.0011)      # -> le=0.01
    h.observe(1.0)         # == last bound -> le=1.0
    h.observe(2.0)         # past the last bound -> +Inf only
    snap = h._snapshot()[0]
    # snapshot buckets are cumulative [bound, count<=bound]
    assert snap["buckets"] == [[0.001, 1], [0.01, 2], [0.1, 2], [1.0, 3]]
    assert snap["count"] == 4
    assert snap["min"] == 0.001 and snap["max"] == 2.0
    s = h.summary()
    assert s["count"] == 4
    assert s["mean"] == pytest.approx((0.001 + 0.0011 + 1.0 + 2.0) / 4)


def test_histogram_quantile_interpolates_and_clamps():
    reg = Om.Registry()
    h = reg.histogram("q", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (1.0, 3.0, 3.5, 7.0):
        h.observe(v)
    # p50 target=2 obs: covered inside the (2,4] bucket
    assert 2.0 <= h.quantile(0.5) <= 4.0
    # quantiles clamp to the observed extremes
    assert h.quantile(0.0) >= 1.0
    assert h.quantile(1.0) <= 7.0
    assert reg.histogram("empty").quantile(0.5) == 0.0


def test_prometheus_text_golden():
    reg = Om.Registry()
    reg.counter("req_total", "requests").inc(3, reason="stop")
    reg.gauge("depth").set(2)
    reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)).observe(0.5)
    assert reg.render_prometheus() == (
        "# TYPE depth gauge\n"
        "depth 2\n"
        "# HELP lat_seconds latency\n"
        "# TYPE lat_seconds histogram\n"
        'lat_seconds_bucket{le="0.1"} 0\n'
        'lat_seconds_bucket{le="1"} 1\n'
        'lat_seconds_bucket{le="+Inf"} 1\n'
        "lat_seconds_sum 0.5\n"
        "lat_seconds_count 1\n"
        "# HELP req_total requests\n"
        "# TYPE req_total counter\n"
        'req_total{reason="stop"} 3\n'
    )


def test_values_flat_view_and_jsonl_line():
    reg = Om.Registry()
    reg.counter("a_total").inc(2)
    reg.histogram("h_seconds", buckets=(1.0,)).observe(0.5)
    flat = reg.values()
    assert flat == {"a_total": 2.0, "h_seconds_count": 1,
                    "h_seconds_sum": 0.5}
    # jsonl_line goes through the GLOBAL registry: merge + valid JSON
    line = Om.jsonl_line({"step": 7})
    payload = json.loads(line)
    assert payload["step"] == 7


# ---- trace recorder ------------------------------------------------------

def test_trace_ring_evicts_oldest_first():
    tr = Otr.TraceRecorder(capacity=4)
    tr.enable()
    for i in range(6):
        tr.instant(f"e{i}", ts=float(i))
    assert len(tr) == 4
    assert [e["name"] for e in tr.events()] == ["e2", "e3", "e4", "e5"]


def test_trace_disabled_records_nothing():
    tr = Otr.TraceRecorder()
    tr.instant("x", ts=0.0)
    tr.span("y", 0.0, 1.0)
    assert len(tr) == 0


def test_chrome_export_schema(tmp_path):
    tr = Otr.TraceRecorder()
    tr.enable()
    tr.name_thread(1, "req 0")
    tr.span("request", 1.0, 1.5, tid=1, args={"reason": "stop"})
    tr.instant("submit", tid=1, ts=1.0)
    doc = tr.to_chrome()
    evs = doc["traceEvents"]
    assert evs[0] == {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
                      "args": {"name": "req 0"}}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == pytest.approx(1.0e6)
    assert x["dur"] == pytest.approx(0.5e6)
    assert x["args"] == {"reason": "stop"}
    i = next(e for e in evs if e["ph"] == "i")
    assert i["s"] == "t" and i["ts"] == pytest.approx(1.0e6)
    # dump() writes the same doc as valid JSON
    out = tmp_path / "trace.json"
    assert tr.dump(str(out)) == 2
    loaded = json.loads(out.read_text())
    assert loaded["traceEvents"] == doc["traceEvents"]


# ---- injectable clock ----------------------------------------------------

def test_fake_clock_advance_and_restore():
    from repro.obs import clock, get_clock
    fc = FakeClock(10.0)
    set_clock(fc)
    try:
        assert clock() == 10.0
        fc.advance(2.5)
        assert clock() == 12.5
    finally:
        set_clock(None)
    assert get_clock() is not fc
    assert clock() > 0.0


# ---- engine integration --------------------------------------------------

def _small_cfg(vocab=128, max_seq=256):
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    return M.ModelConfig(name="obs-test", d_model=32, num_layers=2,
                         num_heads=4, num_kv_heads=4, d_ff=64,
                         vocab_size=vocab, attn=bb, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=max_seq)


@pytest.fixture(scope="module")
def setup():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)
               for n in (19, 40, 33, 11, 26, 17)]
    engine = Engine(cfg, params, max_len=64, capacity=3, prefill_chunk=2)
    return engine, prompts


def _staggered_wave(engine, prompts, max_new=8):
    """2x oversubscribed staggered run: capacity admits 3, the rest queue."""
    reqs = [Request(prompt=p, max_new_tokens=max_new,
                    sampling=SamplingSpec(seed=i))
            for i, p in enumerate(prompts)]
    for r in reqs[:3]:
        engine.submit(r)
    engine.step()
    for r in reqs[3:]:
        engine.submit(r)
    return engine.drain()


def test_per_request_timeline_complete(setup):
    """Every submitted request's timeline closes: a submit instant, an
    admit instant, a queue_wait span and one closing `request` span per
    request id, on that request's tid — across a staggered run where
    half the requests wait in the queue."""
    engine, prompts = setup
    Otr.TRACE.enable()
    Otr.TRACE.clear()
    try:
        results = _staggered_wave(engine, prompts)
        events = Otr.TRACE.events()
    finally:
        Otr.TRACE.disable()
        Otr.TRACE.clear()
    assert len(results) == len(prompts)
    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    rids = {r.request_id for r in results}
    for name in ("submit", "admit", "queue_wait", "request", "first_token"):
        tids = {e["tid"] for e in by_name.get(name, [])}
        assert tids == {rid + 1 for rid in rids}, name
    # the closing span covers submit..finish and carries the verdict
    for e in by_name["request"]:
        assert e["ph"] == "X" and e["dur"] >= 0.0
        assert e["args"]["reason"] in ("stop", "length")
        assert e["args"]["tokens"] > 0
    # engine phase spans land on tid 0
    assert {e["tid"] for e in by_name["engine_step"]} == {0}
    assert "prefill" in by_name and "decode" in by_name


def test_engine_metrics_recorded(setup):
    engine, prompts = setup
    Om.REGISTRY.reset()
    results = _staggered_wave(engine, prompts)
    n = len(results)
    toks = sum(len(r.tokens) for r in results)
    assert Om.REGISTRY.get("serve_requests_submitted_total").value() == n
    assert Om.REGISTRY.get(
        "serve_requests_finished_total").value(reason="length") == n
    assert Om.REGISTRY.get("serve_tokens_generated_total").value() == toks
    assert Om.REGISTRY.get("serve_ttft_seconds").summary()["count"] == n
    assert Om.REGISTRY.get("serve_tpot_seconds").summary()["count"] == n
    assert Om.REGISTRY.get("serve_queue_wait_seconds").summary()["count"] == n
    assert Om.REGISTRY.get("serve_step_seconds").summary()["count"] > 0
    # gauges settle to an idle pool after the drain
    assert Om.REGISTRY.get("serve_pages_in_use").value() == 0
    assert Om.REGISTRY.get("serve_queue_depth").value() == 0


def test_instrumentation_leaves_outputs_unchanged(setup):
    """The digest contract: the same wave with metrics+trace on, and with
    both off, must produce identical token streams."""
    engine, prompts = setup
    res_on = _staggered_wave(engine, prompts)
    Om.disable()
    try:
        res_off = _staggered_wave(engine, prompts)
    finally:
        Om.enable()
    stream = lambda rs: sorted(  # noqa: E731
        (r.request_id % len(prompts), tuple(r.tokens)) for r in rs)
    assert stream(res_on) == stream(res_off)


def test_fake_clock_makes_latency_deterministic(setup):
    """With an injected frozen clock, ttft_s / queue_wait_s are exact:
    submit at t=100, advance to t=105, run -> every latency is 5.0 and
    tpot_s is 0.0 (no wall time passes during decode)."""
    engine, prompts = setup
    fc = FakeClock(100.0)
    set_clock(fc)
    Om.REGISTRY.reset()
    try:
        engine.submit(Request(prompt=prompts[0], max_new_tokens=4,
                              sampling=SamplingSpec(seed=0)))
        fc.advance(5.0)
        results = engine.drain()
    finally:
        set_clock(None)
    (r,) = results
    assert r.ttft_s == 5.0
    assert r.queue_wait_s == 5.0
    assert r.tpot_s == 0.0
    h = Om.REGISTRY.get("serve_ttft_seconds")
    assert h.summary()["min"] == h.summary()["max"] == 5.0


def test_fake_clock_frontend_deadline_expires_without_sleeping(setup):
    """The async front-end reads the same injectable clock: a deadline of
    0 expires on the run loop's first sweep with a frozen FakeClock — no
    wall time passes, no asyncio sleeps — and the expiry lands in
    serve_deadline_expired_total."""
    import asyncio

    from repro.serve import AsyncEngine
    engine, prompts = setup
    set_clock(FakeClock(50.0))
    Om.REGISTRY.reset()
    try:
        async def run():
            front = AsyncEngine(engine)
            sess = await front.submit(prompts[0], 4, deadline_s=0.0)
            r = await sess.result()
            await front.close()
            return r
        r = asyncio.run(run())
    finally:
        set_clock(None)
    assert r.finish_reason == "deadline_exceeded"
    assert r.tokens == []
    assert Om.REGISTRY.get("serve_deadline_expired_total").value() == 1


def test_finish_guards_unset_ttft():
    """Satellite fix: a Result built without an observed first token must
    not dereference ttft_time (tpot_s guarded, negatives clamped)."""
    from repro.serve.batching import SlotState
    s = SlotState(request_id=0, pos=10, generated=3, max_new=8,
                  stop_token=None, tokens=[1, 2, 3], prompt_len=8,
                  admit_step=0)
    assert s.ttft_time is None           # None until the engine observes it


# ---- metrics HTTP server -------------------------------------------------

def test_metrics_server_routes():
    reg = Om.Registry()
    reg.counter("probe_total", "probe").inc(4)
    srv = MetricsServer(port=0, registry=reg).start()
    try:
        base = f"http://127.0.0.1:{srv.port}"
        with urllib.request.urlopen(base + "/metrics", timeout=5) as r:
            body = r.read().decode()
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/plain")
        assert "# TYPE probe_total counter" in body
        assert "probe_total 4" in body
        with urllib.request.urlopen(base + "/metrics.json", timeout=5) as r:
            snap = json.loads(r.read().decode())
        assert snap["probe_total"]["values"][0]["value"] == 4
        with urllib.request.urlopen(base + "/healthz", timeout=5) as r:
            assert r.read() == b"ok\n"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope", timeout=5)
    finally:
        srv.shutdown()
