"""Serving paths: prefill+decode must equal the teacher-forced forward, for
every layer family; bounded BigBird-decode correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.attention import AttentionSpec
from repro.models import decode as D
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def roundtrip_error(cfg, B=2, S=64, maxlen=128):
    if cfg.moe is not None:
        # capacity-dropped MoE legitimately diverges between teacher-forced
        # and incremental decode (drop patterns depend on the token set);
        # test the *architecture* equivalence drop-free.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "patch":
        batch["frontend_embeds"] = jax.random.normal(
            KEY, (B, cfg.frontend_len, cfg.d_model))
    _, cache = D.prefill(params, cfg, batch, maxlen)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    b2 = dict(batch, tokens=toks2, labels=toks2)
    full = M.logits_fn(params, cfg, b2)
    return float(jnp.max(jnp.abs(lg_dec - full[:, S])))


@pytest.mark.parametrize("arch", ["yi-6b", "minicpm-2b", "h2o-danube-1.8b",
                                  "rwkv6-7b", "jamba-1.5-large-398b",
                                  "grok-1-314b", "internvl2-26b",
                                  "gemma3-4b"])
def test_decode_equals_forward(arch):
    cfg = configs.smoke(arch)
    assert roundtrip_error(cfg) < 2e-3


def test_encdec_decode_consistency():
    cfg = configs.smoke("whisper-base")
    params = M.init(cfg, KEY)
    B, Se = 2, 64
    frames = jax.random.normal(KEY, (B, Se, cfg.d_model))
    S_dec = 16
    toks = jax.random.randint(KEY, (B, S_dec), 4, cfg.vocab_size)
    batch = {"frames": frames, "tokens": toks, "labels": toks}
    _, cache = D.prefill(params, cfg, batch, cfg.dec_len)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S_dec)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    full = M.logits_fn(params, cfg, dict(batch, tokens=toks2, labels=toks2))
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S_dec]))) < 2e-3


@pytest.mark.slow
def test_bigbird_bounded_decode_matches_pattern_attention():
    """Decode with the BigBird cache read must equal the teacher-forced
    forward of the BigBird-causal model (the same graph)."""
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    cfg = M.ModelConfig(name="bbd", d_model=32, num_layers=2, num_heads=4,
                        num_kv_heads=4, d_ff=64, vocab_size=128, attn=bb,
                        dtype=jnp.float32, scan_layers=False, remat="none",
                        loss_chunk=32)
    params = M.init(cfg, KEY)
    B, S, MAX = 1, 120, 128   # decode at pos 120 -> block 15 of 16
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    _, cache = D.prefill(params, cfg, batch, MAX)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    full = M.logits_fn(params, cfg, dict(batch, tokens=toks2, labels=toks2))
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S]))) < 2e-3


def test_bounded_decode_reads_only_pattern_blocks():
    """Perturbing cache outside the pattern must not change the output."""
    from repro.core import patterns as P
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=2, num_global_blocks=1,
                       num_random_blocks=1, seed=3)
    cfg = M.ModelConfig(name="bbd2", d_model=32, num_layers=1, num_heads=2,
                        num_kv_heads=2, d_ff=64, vocab_size=128, attn=bb,
                        dtype=jnp.float32, scan_layers=False, remat="none",
                        loss_chunk=32)
    params = M.init(cfg, KEY)
    B, S, MAX = 1, 120, 128
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    _, cache = D.prefill(params, cfg, {"tokens": toks, "labels": toks}, MAX)
    nxt = jnp.array([[7]], jnp.int32)
    base, _ = D.decode_step(params, cfg, cache, nxt, S)
    # find a cache block NOT in the pattern row for query block 15
    pat = P.build_pattern(bb.bigbird_config(MAX), MAX)
    row = set(pat.key_blocks[S // 8][pat.key_mask[S // 8]].tolist())
    outside = [j for j in range(1, 14) if j not in row][0]
    c2 = jax.tree.map(lambda x: x, cache)
    kx = c2["layer0"]["k"].at[:, :, outside * 8:(outside + 1) * 8].add(9.0)
    c2["layer0"] = dict(c2["layer0"], k=kx)
    pert, _ = D.decode_step(params, cfg, c2, nxt, S)
    np.testing.assert_allclose(base, pert, atol=1e-5)


def test_cache_spec_shapes_match_prefill():
    cfg = configs.smoke("jamba-1.5-large-398b")
    spec = D.cache_spec(cfg, B=2, max_len=128, abstract=True)
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 64), 4, cfg.vocab_size)
    _, cache = D.prefill(params, cfg, {"tokens": toks, "labels": toks}, 128)
    flat_spec = jax.tree.leaves(spec)
    flat_cache = jax.tree.leaves(cache)
    assert len(flat_spec) == len(flat_cache)
    for s, c in zip(jax.tree.leaves(jax.tree.map(lambda x: x.shape, spec)),
                    jax.tree.leaves(jax.tree.map(lambda x: x.shape, cache))):
        assert s == c
