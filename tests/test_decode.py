"""Serving paths: prefill+decode must equal the teacher-forced forward, for
every layer family; bounded BigBird-decode correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.attention import AttentionSpec
from repro.models import decode as D
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def roundtrip_error(cfg, B=2, S=64, maxlen=128):
    if cfg.moe is not None:
        # capacity-dropped MoE legitimately diverges between teacher-forced
        # and incremental decode (drop patterns depend on the token set);
        # test the *architecture* equivalence drop-free.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "patch":
        batch["frontend_embeds"] = jax.random.normal(
            KEY, (B, cfg.frontend_len, cfg.d_model))
    _, cache = D.prefill(params, cfg, batch, maxlen)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    b2 = dict(batch, tokens=toks2, labels=toks2)
    full = M.logits_fn(params, cfg, b2)
    return float(jnp.max(jnp.abs(lg_dec - full[:, S])))


@pytest.mark.parametrize("arch", ["yi-6b", "minicpm-2b", "h2o-danube-1.8b",
                                  "rwkv6-7b", "jamba-1.5-large-398b",
                                  "grok-1-314b", "internvl2-26b",
                                  "gemma3-4b"])
def test_decode_equals_forward(arch):
    cfg = configs.smoke(arch)
    assert roundtrip_error(cfg) < 2e-3


def test_encdec_decode_consistency():
    cfg = configs.smoke("whisper-base")
    params = M.init(cfg, KEY)
    B, Se = 2, 64
    frames = jax.random.normal(KEY, (B, Se, cfg.d_model))
    S_dec = 16
    toks = jax.random.randint(KEY, (B, S_dec), 4, cfg.vocab_size)
    batch = {"frames": frames, "tokens": toks, "labels": toks}
    _, cache = D.prefill(params, cfg, batch, cfg.dec_len)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S_dec)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    full = M.logits_fn(params, cfg, dict(batch, tokens=toks2, labels=toks2))
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S_dec]))) < 2e-3


@pytest.mark.slow
def test_bigbird_bounded_decode_matches_pattern_attention():
    """Decode with the BigBird cache read must equal the teacher-forced
    forward of the BigBird-causal model (the same graph)."""
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    cfg = M.ModelConfig(name="bbd", d_model=32, num_layers=2, num_heads=4,
                        num_kv_heads=4, d_ff=64, vocab_size=128, attn=bb,
                        dtype=jnp.float32, scan_layers=False, remat="none",
                        loss_chunk=32)
    params = M.init(cfg, KEY)
    B, S, MAX = 1, 120, 128   # decode at pos 120 -> block 15 of 16
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    _, cache = D.prefill(params, cfg, batch, MAX)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    full = M.logits_fn(params, cfg, dict(batch, tokens=toks2, labels=toks2))
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S]))) < 2e-3


def test_bounded_decode_reads_only_pattern_blocks():
    """Perturbing cache outside the pattern must not change the output."""
    from repro.core import patterns as P
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=2, num_global_blocks=1,
                       num_random_blocks=1, seed=3)
    cfg = M.ModelConfig(name="bbd2", d_model=32, num_layers=1, num_heads=2,
                        num_kv_heads=2, d_ff=64, vocab_size=128, attn=bb,
                        dtype=jnp.float32, scan_layers=False, remat="none",
                        loss_chunk=32)
    params = M.init(cfg, KEY)
    B, S, MAX = 1, 120, 128
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    _, cache = D.prefill(params, cfg, {"tokens": toks, "labels": toks}, MAX)
    nxt = jnp.array([[7]], jnp.int32)
    base, _ = D.decode_step(params, cfg, cache, nxt, S)
    # find a cache block NOT in the pattern row for query block 15
    pat = P.build_pattern(bb.bigbird_config(MAX), MAX)
    row = set(pat.key_blocks[S // 8][pat.key_mask[S // 8]].tolist())
    outside = [j for j in range(1, 14) if j not in row][0]
    c2 = jax.tree.map(lambda x: x, cache)
    kx = c2["layer0"]["k"].at[:, :, outside * 8:(outside + 1) * 8].add(9.0)
    c2["layer0"] = dict(c2["layer0"], k=kx)
    pert, _ = D.decode_step(params, cfg, c2, nxt, S)
    np.testing.assert_allclose(base, pert, atol=1e-5)


def test_cache_spec_shapes_match_prefill():
    cfg = configs.smoke("jamba-1.5-large-398b")
    spec = D.cache_spec(cfg, B=2, max_len=128, abstract=True)
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 64), 4, cfg.vocab_size)
    _, cache = D.prefill(params, cfg, {"tokens": toks, "labels": toks}, 128)
    flat_spec = jax.tree.leaves(spec)
    flat_cache = jax.tree.leaves(cache)
    assert len(flat_spec) == len(flat_cache)
    for s, c in zip(jax.tree.leaves(jax.tree.map(lambda x: x.shape, spec)),
                    jax.tree.leaves(jax.tree.map(lambda x: x.shape, cache))):
        assert s == c


# --------------------------------------------------------------------------
# paged cache: layout parity, chunked prefill, fallback boundary
# --------------------------------------------------------------------------

def _bb_cfg(b=8, w=3, g=1, r=1, layers=2, maxseq=256, kind="bigbird",
            impl="blockified"):
    spec = AttentionSpec(kind=kind, causal=True, block_size=b,
                         num_window_blocks=w, num_global_blocks=g,
                         num_random_blocks=r, impl=impl)
    return M.ModelConfig(name="paged", d_model=32, num_layers=layers,
                         num_heads=4, num_kv_heads=2, d_ff=64,
                         vocab_size=128, attn=spec, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=maxseq)


def _paged_from_contiguous(cfg, cache, maxlen, num_pages, perm):
    """Copy a contiguous cache (B, H, maxlen, dh) into a paged tree using
    the (B, max_pages) page assignment `perm`."""
    b = D.page_size_for(cfg)
    paged = D.cache_spec(cfg, perm.shape[0], maxlen, abstract=False,
                         num_pages=num_pages)
    for grp in cache:
        for key in ("k", "v"):
            src = cache[grp][key]          # (B, H, maxlen, dh)
            dst = paged[grp][key]          # (P, H, b, dh)
            for i in range(perm.shape[0]):
                for j in range(perm.shape[1]):
                    dst = dst.at[perm[i, j]].set(
                        src[i, :, j * b:(j + 1) * b])
            paged[grp][key] = dst
    return paged


@pytest.mark.parametrize("maxlen,expect_bb", [(64, True), (32, False)])
def test_paged_decode_step_bitwise_matches_contiguous(maxlen, expect_bb):
    """decode_step over the paged cache must equal the slot-contiguous
    cache EXACTLY (same gather order, same contractions) — in both the
    bounded-bigbird read and the full-fallback read (short cache)."""
    cfg = _bb_cfg()
    params = M.init(cfg, KEY)
    B, S = 2, maxlen - 9
    toks = jax.random.randint(KEY, (B, S), 4, cfg.vocab_size)
    _, cache = D.prefill(params, cfg, {"tokens": toks, "labels": toks}, maxlen)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 4, cfg.vocab_size)
    pos = jnp.asarray([S, S - 7], jnp.int32)

    b = D.page_size_for(cfg)
    max_pages = maxlen // b
    P = 2 * B * max_pages + 1
    perm = np.random.default_rng(7).permutation(
        np.arange(1, P))[:B * max_pages].reshape(B, max_pages).astype(np.int32)
    paged = _paged_from_contiguous(cfg, cache, maxlen, P, perm)

    lg_c, _ = D.decode_step(params, cfg, cache, nxt, pos)
    lg_p, newp = D.decode_step(params, cfg, paged, nxt, pos,
                               page_tables=jnp.asarray(perm))
    np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_p))
    # the paged write landed each row's KV on its own page at pos % b
    for i in range(B):
        pg = perm[i, int(pos[i]) // b]
        row = newp["layer0"]["k"][pg, :, int(pos[i]) % b]
        assert float(jnp.abs(row).sum()) > 0


def test_chunked_prefill_equals_one_shot():
    """prefill_chunk over [0,C), [C,2C), ... must build the same cache and
    final-token logits as one bucketed one-shot prefill."""
    cfg = _bb_cfg()
    params = M.init(cfg, KEY)
    L, maxlen, C = 40, 64, 16
    b = D.page_size_for(cfg)
    prompt = jax.random.randint(KEY, (1, L), 4, cfg.vocab_size)

    bucket = 64                                    # pow2 bucket of 40
    toks_pad = jnp.zeros((1, bucket), jnp.int32).at[:, :L].set(prompt)
    lg_ref, cache_ref = D.prefill(params, cfg, {"tokens": toks_pad}, bucket,
                                  last_index=jnp.asarray([L - 1]))

    max_pages = maxlen // b
    P = 2 * max_pages
    need = -(-L // b)
    pt = np.zeros((1, max_pages), np.int32)
    pt[0, :need] = np.arange(1, need + 1)
    paged = D.cache_spec(cfg, 1, maxlen, abstract=False, num_pages=P)
    lg = None
    for start in range(0, -(-L // C) * C, C):
        toks = np.zeros((1, C), np.int32)
        real = np.asarray(prompt[0, start:start + C])
        toks[0, :real.size] = real
        lg, paged = D.prefill_chunk(
            params, cfg, paged, jnp.asarray(toks), jnp.asarray(pt),
            start=start, last_index=jnp.asarray([L - 1]), bucket_len=bucket)
    np.testing.assert_allclose(lg, lg_ref, atol=2e-5, rtol=2e-5)
    # written pages hold the same KV rows the one-shot cache holds
    for grp in ("layer0", "layer1"):
        for key in ("k", "v"):
            for j in range(need):
                hi = min((j + 1) * b, L)
                np.testing.assert_allclose(
                    paged[grp][key][pt[0, j], :, :hi - j * b],
                    cache_ref[grp][key][0, :, j * b:hi], atol=2e-5)


def test_bounded_decode_fallback_boundary():
    """Cache lengths just below / at / above the pattern-coverage threshold
    T = g+w+r blocks: below T the bigbird read must fall back to full
    (bit-identical to a full-attention spec); at and above T the bounded
    read must match the teacher-forced pattern forward."""
    b, w, g, r = 8, 3, 1, 1
    T = g + w + r                                   # 5 blocks -> 40 tokens
    for nb, bounded in ((T - 1, False), (T, True), (T + 3, True)):
        MAX = nb * b
        cfg = _bb_cfg(b=b, w=w, g=g, r=r, maxseq=MAX)
        params = M.init(cfg, KEY)
        S = MAX - 1
        toks = jax.random.randint(KEY, (1, S), 4, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        _, cache = D.prefill(params, cfg, batch, MAX)
        nxt = jax.random.randint(jax.random.PRNGKey(1), (1, 1), 4,
                                 cfg.vocab_size)
        lg_dec, _ = D.decode_step(params, cfg, cache, nxt, S)
        toks2 = jnp.concatenate([toks, nxt], axis=1)
        full = M.logits_fn(params, cfg, dict(batch, tokens=toks2,
                                             labels=toks2))
        assert float(jnp.max(jnp.abs(lg_dec - full[:, S]))) < 2e-3, \
            f"nb={nb} parity with teacher-forced forward"
        if not bounded:
            # below threshold the bigbird cache read IS the full read
            cfg_full = _bb_cfg(b=b, w=w, g=g, r=r, maxseq=MAX, kind="full")
            lg_full, _ = D.decode_step(params, cfg_full, cache, nxt, S)
            np.testing.assert_array_equal(np.asarray(lg_dec),
                                          np.asarray(lg_full))
