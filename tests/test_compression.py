"""Cross-pod int8 gradient sync (optim/compression.py): error feedback
keeps the compressed sync unbiased over steps, the on-wire reduction
really is int-typed in the compiled program, the single-pod case is the
exact identity, and the shard-mapped closure is built once per tree."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.optim import compression as Comp

F32 = jnp.float32


def _pod_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("pod",))


def _tree(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((4, 8)) * scale, F32),
        "b": jnp.asarray(rng.standard_normal((8,)) * scale, F32),
    }


def _pspecs(tree):
    return jax.tree.map(lambda _: PartitionSpec(), tree)


def test_single_pod_mesh_without_axis_is_identity():
    """A mesh lacking the pod axis is the single-pod case: grads and the
    residual pass through bit-identical (no quantization noise)."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    grads = _tree(0)
    err = Comp.init_error_state(grads)
    out, new_err = Comp.compressed_grad_sync(grads, err, mesh,
                                             _pspecs(grads), axis="pod")
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(grads)):
        np.testing.assert_array_equal(a, b)
    for e in jax.tree.leaves(new_err):
        np.testing.assert_array_equal(e, jnp.zeros_like(e))


def test_one_pod_quantizes_but_error_feedback_corrects():
    """n_pods=1 still quantizes (round-trip through int8), so a single
    call is lossy — but grad + err always reconstructs the true running
    sum: the defining invariant of error feedback."""
    mesh = _pod_mesh()
    grads = _tree(1)
    err = Comp.init_error_state(grads)
    out, new_err = Comp.compressed_grad_sync(grads, err, mesh,
                                             _pspecs(grads), axis="pod")
    for o, e, g in zip(jax.tree.leaves(out), jax.tree.leaves(new_err),
                       jax.tree.leaves(grads)):
        # quantization error is bounded by half a quantization step
        step = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(o - g))) <= 0.5 * step + 1e-7
        # out + err == g exactly in f32 arithmetic terms
        np.testing.assert_allclose(np.asarray(o + e), np.asarray(g),
                                   rtol=0, atol=1e-6)


def test_error_feedback_converges_over_repeated_steps():
    """Feeding the SAME gradient repeatedly, the error-feedback average
    converges to the true gradient (residual cannot accumulate)."""
    mesh = _pod_mesh()
    g = _tree(2)
    err = Comp.init_error_state(g)
    total = jax.tree.map(jnp.zeros_like, g)
    steps = 64
    for _ in range(steps):
        out, err = Comp.compressed_grad_sync(g, err, mesh, _pspecs(g),
                                             axis="pod")
        total = jax.tree.map(lambda a, o: a + o, total, out)
    for t, gg in zip(jax.tree.leaves(total), jax.tree.leaves(g)):
        mean = np.asarray(t) / steps
        # the residual is bounded, so the mean error decays like 1/steps
        step = float(jnp.max(jnp.abs(gg))) / 127.0
        assert float(np.max(np.abs(mean - np.asarray(gg)))) \
            <= step / steps + 1e-6


def test_on_wire_dtype_is_integer_in_jaxpr():
    """The cross-pod psum must reduce an integer array — the whole point
    of the scheme.  Assert from the traced jaxpr, not from trust."""
    mesh = _pod_mesh()
    g = _tree(3)
    err = Comp.init_error_state(g)

    def f(grads, err):
        return Comp.compressed_grad_sync(g, err, mesh, _pspecs(g),
                                         axis="pod")
    text = str(jax.make_jaxpr(f)(g, err))
    psums = [ln for ln in text.splitlines() if "psum" in ln]
    assert psums, "no psum in traced sync"
    assert any("i32" in ln or "int32" in ln for ln in psums), text
    assert "i8" in text, "int8 quantization missing from jaxpr"


def test_shard_map_closure_is_cached_per_tree():
    """Same (mesh, treedef, pspecs, axis) -> one cached closure; a
    different tree structure adds exactly one more."""
    mesh = _pod_mesh()
    g = _tree(4)
    err = Comp.init_error_state(g)
    Comp._SYNC_CACHE.clear()
    Comp.compressed_grad_sync(g, err, mesh, _pspecs(g), axis="pod")
    assert Comp.sync_cache_size() == 1
    Comp.compressed_grad_sync(g, err, mesh, _pspecs(g), axis="pod")
    assert Comp.sync_cache_size() == 1          # reused, not rebuilt
    g2 = {"only": jnp.ones((3,), F32)}
    Comp.compressed_grad_sync(g2, Comp.init_error_state(g2), mesh,
                              _pspecs(g2), axis="pod")
    assert Comp.sync_cache_size() == 2


def test_clip_before_round_never_exceeds_int8_range():
    """An outlier landing exactly on the clip rail must round INSIDE
    int8: with round-after-clip, 127.4999.. stays 127; the old
    clip-after-round path aliased round(127.5) -> 128 -> overflow."""
    mesh = _pod_mesh()
    # values chosen so g/scale hits non-integer points near +-127
    g = {"w": jnp.asarray([1.0, -1.0, 0.9999, -0.9999, 127.3 / 127.0],
                          F32)}
    err = Comp.init_error_state(g)
    out, _ = Comp.compressed_grad_sync(g, err, mesh, _pspecs(g),
                                       axis="pod")
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(out["w"]))) <= 127.0 * scale + 1e-7
