"""Quantized KV pages + host-memory swap (DESIGN.md §Paged cache):

* int8 page stores carry per-(page, kv-head) f32 scales; the pool's
  write paths quantize, the kernels dequantize in VMEM — the Pallas and
  XLA paths must agree on the SAME int8 pages;
* the host swap tier is exact: pages round-trip host memory bitwise,
  so a starved pool with host_swap produces token streams identical to
  an ample pool's, while admitting past physical page capacity;
* every pool lifecycle invariant (refcounts, CoW prefix sharing,
  reservations, abort) must hold unchanged under both features.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec
from repro.models import decode as D
from repro.models import model as M
from repro.serve import Engine, Request, SamplingSpec, SpecConfig
from repro.serve.batching import PagePool, SlotState

KEY = jax.random.PRNGKey(0)


def _small_cfg(vocab=128, max_seq=256):
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    return M.ModelConfig(name="kvc-test", d_model=32, num_layers=2,
                         num_heads=4, num_kv_heads=4, d_ff=64,
                         vocab_size=vocab, attn=bb, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=max_seq)


def _pool_empty(pool):
    return (pool.pages_in_use == 0 and pool.pages_reserved == 0
            and pool.pages_host == 0
            and sum(len(f) for f in pool._free) == pool.num_pages - 1)


@pytest.fixture(scope="module")
def built():
    cfg = _small_cfg()
    return cfg, M.init(cfg, KEY)


def _reqs(n=5, seed=7, base=20, step=3, max_new=12, prefix=None):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        body = rng.integers(4, 127, size=base + step * i).astype(np.int32)
        if prefix is not None:
            body = np.concatenate([prefix, body])
        out.append(Request(prompt=body, max_new_tokens=max_new,
                           sampling=SamplingSpec(seed=i), request_id=i))
    return out


def _run(cfg, params, reqs, **kw):
    eng = Engine(cfg, params, max_len=64, capacity=3, **kw)
    for r in reqs:
        eng.submit(Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                           sampling=r.sampling, request_id=r.request_id))
    return eng, {r.request_id: r.tokens for r in eng.drain()}


# --------------------------------------------------------------------------
# int8 page stores
# --------------------------------------------------------------------------

def test_int8_cache_layout_and_bytes():
    """int8 pool: k/v stores go int8, scale leaves ks/vs appear with
    per-(page, kv-head) f32 granularity, and bytes/page drop under 0.3x
    (the satellite's >= 40% KV cut, with scale overhead included)."""
    cfg = _small_cfg()
    pool8 = PagePool(cfg, capacity=2, max_len=64, kv_dtype="int8")
    l0 = pool8.cache["layer0"]
    assert l0["k"].dtype == jnp.int8 and l0["v"].dtype == jnp.int8
    assert l0["ks"].dtype == jnp.float32
    assert l0["ks"].shape == (pool8.num_pages, cfg.num_kv_heads)
    poolf = PagePool(cfg, capacity=2, max_len=64)
    assert "ks" not in poolf.cache["layer0"]
    ratio = pool8.kv_bytes_per_page() / poolf.kv_bytes_per_page()
    assert ratio < 0.3, ratio


def test_quantize_pages_roundtrip_error_bound():
    """Dequantized error <= half a quantization step per (page, head)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((3, 4, 8, 8)),
                    jnp.float32)
    q, s = D._quantize_pages(x)
    assert q.dtype == jnp.int8 and s.shape == (3, 4)
    err = jnp.abs(q.astype(jnp.float32) * s[..., None, None] - x)
    assert float(jnp.max(err - 0.5 * s[..., None, None])) <= 1e-6
    # all-zero pages quantize to zeros with the epsilon scale, not NaN
    q0, s0 = D._quantize_pages(jnp.zeros((1, 2, 8, 8)))
    assert float(jnp.max(jnp.abs(q0))) == 0 and bool(jnp.all(s0 > 0))


def test_int8_paged_decode_pallas_vs_xla_parity():
    """The Pallas kernel dequantizing int8 in VMEM must match the XLA
    path fed the SAME dequantized pages — quantization error lives in
    the pages, never in the kernel."""
    from repro.kernels import ops
    cfg = _small_cfg()
    bbc = cfg.attn_spec(cfg.layer_pattern[0]).bigbird_config(64)
    rng = np.random.default_rng(1)
    B, Hq, Hkv, dh, b = 2, 4, 4, 8, 8
    P, npages = 16, 8
    kc = jnp.asarray(rng.integers(-127, 128, (P, Hkv, b, dh)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, (P, Hkv, b, dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.1, (P, Hkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.1, (P, Hkv)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, dh)), jnp.float32)
    pt = jnp.asarray(rng.integers(1, P, (B, npages)), jnp.int32)
    pos = jnp.asarray([13, 55], jnp.int32)
    out_q = ops.bigbird_paged_decode_attn(q, kc, vc, pt, pos, bbc,
                                          k_scale=ks, v_scale=vs)
    kf = kc.astype(jnp.float32) * ks[:, :, None, None]
    vf = vc.astype(jnp.float32) * vs[:, :, None, None]
    out_f = ops.bigbird_paged_decode_attn(q, kf, vf, pt, pos, bbc)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_f),
                               atol=2e-5)


def test_int8_engine_lifecycle_and_prefix_sharing(built):
    """Oversubscribed int8 engine with a shared prompt prefix: every
    request finishes full-length, prefix pages are shared (CoW refcounts
    survive quantized writes), and the pool drains clean."""
    cfg, params = built
    prefix = np.arange(4, 4 + 8, dtype=np.int32)    # one full page
    reqs = _reqs(5, prefix=prefix)
    eng, res = _run(cfg, params, reqs, kv_dtype="int8")
    assert set(res) == {0, 1, 2, 3, 4}
    assert all(len(res[i]) == 12 for i in res)
    st = eng.stats()
    assert st.prefix_hits > 0
    assert _pool_empty(eng.pool)


def test_int8_score_nll_close_to_f32(built):
    """Teacher-forced NLL through the int8 paged path stays near the f32
    engine's — the quality number the CI gate bands."""
    cfg, params = built
    reqs = _reqs(1)
    engf = Engine(cfg, params, max_len=64, capacity=1)
    eng8 = Engine(cfg, params, max_len=64, capacity=1, kv_dtype="int8")
    engf.submit(reqs[0])
    toks = engf.drain()[0].tokens
    lp_f = engf.score(reqs[0].prompt, toks)
    lp_8 = eng8.score(reqs[0].prompt, toks)
    assert lp_f.shape == (12,) and np.all(lp_f <= 0)
    assert abs(float(np.mean(lp_f) - np.mean(lp_8))) < 0.5
    assert _pool_empty(engf.pool) and _pool_empty(eng8.pool)


def test_int8_spec_decode_completes_clean(built):
    """Speculative draft/verify over int8 pages: rollback (page-table
    truncation + RMW scale state) must leave the pool consistent."""
    cfg, params = built
    reqs = _reqs(4, max_new=10)
    eng, res = _run(cfg, params, reqs, kv_dtype="int8",
                    spec=SpecConfig(k=3, provider="ngram"))
    assert all(len(res[i]) == 10 for i in res)
    assert _pool_empty(eng.pool)


# --------------------------------------------------------------------------
# host-memory swap tier
# --------------------------------------------------------------------------

def test_pool_swap_roundtrip_bitwise():
    """swap_out releases pages + reservation and parks the stores on
    host; swap_in restores them bitwise into fresh pages."""
    cfg = _small_cfg()
    pool = PagePool(cfg, capacity=2, max_len=64, kv_dtype="int8")
    prompt = np.random.default_rng(1).integers(0, 127, 17).astype(np.int32)
    st = SlotState(request_id=1, pos=17, generated=0, max_new=20,
                   stop_token=None, tokens=[], prompt_len=17, admit_step=0)
    pool.allocate(0, prompt, 20, graph_key="g", state=st)
    idx = jnp.asarray(st.pages)
    for key in ("k", "v"):
        c = pool.cache["layer0"][key]
        pool.cache["layer0"][key] = c.at[idx].set(
            (jnp.arange(c[idx].size, dtype=jnp.float32)
             .reshape(c[idx].shape) % 100).astype(c.dtype))
    for key in ("ks", "vs"):
        pool.cache["layer0"][key] = \
            pool.cache["layer0"][key].at[idx].set(0.5)
    before = {k: np.asarray(v[idx]) for k, v in pool.cache["layer0"].items()}
    free0 = sum(len(f) for f in pool._free)
    resv, res0 = st.reserved, pool._reserved[0]
    pool.swap_out(0)
    assert pool.slots[0].phase == "swapped"
    assert pool.swapped_slots() == [0]
    assert pool.pages_host == len(before["k"])
    assert sum(len(f) for f in pool._free) == free0 + len(before["k"])
    assert pool._reserved[0] == res0 - resv
    assert 0 not in pool.decode_slots()          # excluded from batching
    pool.swap_in(0, prompt, "g")
    assert pool.slots[0].phase == "decode" and pool.pages_host == 0
    assert pool._reserved[0] == res0
    after = {k: np.asarray(
        pool.cache["layer0"][k][jnp.asarray(pool.slots[0].pages)])
        for k in before}
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])


def test_host_swap_streams_bitwise_and_admits_past_capacity(built):
    """THE acceptance test: a pool too small for the workload, with
    host_swap, finishes every request with streams bitwise-identical to
    an ample pool — and page exhaustion no longer hard-queues (real swap
    traffic, aggregate footprint past the physical page count)."""
    cfg, params = built
    reqs = _reqs(5)
    engf, resf = _run(cfg, params, reqs)
    engs, ress = _run(cfg, params, reqs, host_swap=True, num_pages=9)
    assert ress == resf
    st = engs.stats()
    assert st.swap_out > 0 and st.swap_in > 0
    assert st.pages_host == 0
    # the ample run's peak working set exceeds the tiny pool's usable
    # pages: only the swap tier made this workload fit
    assert engf.stats().peak_pages_in_use > engs.pool.num_pages - 1
    assert _pool_empty(engs.pool)


def test_host_swap_with_shared_prefix(built):
    """Swap cycles while co-residents share prefix pages: a swapped-out
    sharer must not strand or corrupt the shared pages, and swap_in
    reattaches via the prefix index (content-addressed, still bitwise)."""
    cfg, params = built
    prefix = np.arange(4, 4 + 8, dtype=np.int32)
    reqs = _reqs(5, prefix=prefix)
    engf, resf = _run(cfg, params, reqs)
    engs, ress = _run(cfg, params, reqs, host_swap=True, num_pages=10)
    assert ress == resf
    assert engs.stats().swap_out > 0
    assert _pool_empty(engs.pool)


def test_abort_swapped_request_releases_host_buffer(built):
    """Aborting a request while it sits in the host tier frees its host
    blob and leaves the remaining workload unaffected."""
    cfg, params = built
    reqs = _reqs(5)
    eng = Engine(cfg, params, max_len=64, capacity=3, host_swap=True,
                 num_pages=9)
    for r in reqs:
        eng.submit(r)
    victim = None
    for _ in range(400):
        eng.step()
        swapped = eng.swapped_requests()
        if swapped:
            victim = swapped[0]
            break
    assert victim is not None, "workload produced no swap traffic"
    assert eng.pool.pages_host > 0
    res = eng.abort(victim)
    assert res is not None and res.finish_reason == "aborted"
    assert victim not in eng.swapped_requests()
    rest = {r.request_id: r.tokens for r in eng.drain()}
    assert set(rest) == {0, 1, 2, 3, 4} - {victim}
    assert all(len(t) == 12 for t in rest.values())
    assert _pool_empty(eng.pool)


def test_host_swap_requires_unsharded_lm(built):
    cfg, params = built
    from repro.serve import mesh as Mx
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=64, capacity=3, host_swap=True,
               mesh=Mx.parse_mesh("1x1"))


def test_int8_plus_host_swap_compose(built):
    """Both features together: quantized pages swap host and back; the
    run must equal the int8-no-swap run bitwise (swap adds no loss on
    top of quantization)."""
    cfg, params = built
    reqs = _reqs(5)
    _, res8 = _run(cfg, params, reqs, kv_dtype="int8")
    engs, ress = _run(cfg, params, reqs, kv_dtype="int8", host_swap=True,
                      num_pages=9)
    assert ress == res8
    assert engs.stats().swap_out > 0
    assert _pool_empty(engs.pool)
