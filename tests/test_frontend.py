"""Async streaming front-end: stream == drain bit-identity, deadline and
shedding semantics, and cancellation propagating into the page pool.

Tests drive the event loop through `asyncio.run` directly so they run
with or without pytest-asyncio installed.
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec
from repro.models import model as M
from repro.serve import AsyncEngine, Engine, Request, SamplingSpec

KEY = jax.random.PRNGKey(0)


def _small_cfg(vocab=128, max_seq=256):
    bb = AttentionSpec(
        kind="bigbird",
        causal=True,
        block_size=8,
        num_window_blocks=3,
        num_global_blocks=1,
        num_random_blocks=1,
    )
    return M.ModelConfig(
        name="frontend-test",
        d_model=32,
        num_layers=2,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=vocab,
        attn=bb,
        dtype=jnp.float32,
        scan_layers=False,
        remat="none",
        loss_chunk=32,
        max_seq=max_seq,
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)
        for n in (19, 40, 33, 11)
    ]
    return cfg, params, prompts


def _drain_reference(cfg, params, prompts, max_new=8):
    eng = Engine(cfg, params, max_len=64, capacity=4, prefill_chunk=2)
    for i, p in enumerate(prompts):
        eng.submit(
            Request(prompt=p, max_new_tokens=max_new, sampling=SamplingSpec(seed=i))
        )
    return {r.request_id: tuple(r.tokens) for r in eng.drain()}


def _pool_empty(pool):
    return (
        pool.pages_in_use == 0
        and pool.pages_reserved == 0
        and sum(len(f) for f in pool._free) == pool.num_pages - 1
    )


def test_streamed_greedy_bit_identical_to_drain(setup):
    """`async for tok in session` must yield exactly the tokens the
    synchronous Engine.drain path produces — solo ordering and staggered
    submission, with dispatch pipelining on."""
    cfg, params, prompts = setup
    ref = _drain_reference(cfg, params, prompts)

    async def run(stagger):
        eng = Engine(
            cfg, params, max_len=64, capacity=4, prefill_chunk=2, dispatch_depth=2
        )
        front = AsyncEngine(eng)
        sessions = []
        for i, p in enumerate(prompts):
            sessions.append(await front.submit(p, 8, sampling=SamplingSpec(seed=i)))
            if stagger:
                await asyncio.sleep(0.02)
        streams = []
        for s in sessions:
            toks = [t async for t in s]
            r = await s.result()
            assert r.finish_reason == "length"
            assert tuple(r.tokens) == tuple(toks)  # stream == Result
            streams.append(tuple(toks))
        await front.close()
        assert _pool_empty(eng.pool)
        return streams

    assert asyncio.run(run(False)) == [ref[i] for i in range(4)]
    assert asyncio.run(run(True)) == [ref[i] for i in range(4)]


def test_deadline_expiry_typed_result_without_leaking_pages(setup):
    """A request whose TTFT deadline lapses while queued (or resident but
    pre-first-token) finishes with finish_reason="deadline_exceeded"; its
    pages and reservation are fully released."""
    cfg, params, prompts = setup

    async def run():
        eng = Engine(cfg, params, max_len=64, capacity=1, prefill_chunk=2)
        front = AsyncEngine(eng)
        keep = await front.submit(prompts[0], 8, sampling=SamplingSpec(seed=0))
        # capacity 1: this one queues behind `keep` and must expire there
        doomed = await front.submit(prompts[1], 8, deadline_s=0.0)
        r = await doomed.result()
        assert r.finish_reason == "deadline_exceeded" and r.tokens == []
        # resident expiry: admitted (slot held) but deadline fires before
        # its first streamed token
        doomed2 = await front.submit(prompts[2], 8, deadline_s=1e-6)
        r2 = await doomed2.result()
        assert r2.finish_reason == "deadline_exceeded"
        rk = await keep.result()
        assert rk.finish_reason == "length" and len(rk.tokens) == 8
        await front.close()
        assert _pool_empty(eng.pool)

    asyncio.run(run())


def test_queue_full_shedding_respects_priority(setup):
    """At max_queue, a high-priority submit sheds the lowest-priority
    queued request; a low-priority submit sheds itself — both get a typed
    "shed" Result immediately and never touch the engine."""
    cfg, params, prompts = setup

    async def run():
        eng = Engine(cfg, params, max_len=64, capacity=1, prefill_chunk=2)
        front = AsyncEngine(eng, max_queue=2)
        busy = await front.submit(prompts[0], 8)  # occupies the slot
        await asyncio.sleep(0.05)
        low = await front.submit(prompts[1], 4, priority=1)
        high = await front.submit(prompts[2], 4, priority=5)
        mid = await front.submit(prompts[3], 4, priority=3)  # sheds `low`
        r_low = await low.result()
        assert r_low.finish_reason == "shed" and r_low.tokens == []
        worse = await front.submit(prompts[0], 4, priority=0)  # sheds itself
        r_worse = await worse.result()
        assert r_worse.finish_reason == "shed"
        done = [await s.result() for s in (busy, high, mid)]
        assert all(r.finish_reason == "length" for r in done)
        await front.close()
        assert _pool_empty(eng.pool)

    asyncio.run(run())


def test_priority_orders_admission(setup):
    """Queued requests admit best-priority-first regardless of arrival."""
    cfg, params, prompts = setup

    async def run():
        eng = Engine(cfg, params, max_len=64, capacity=1, prefill_chunk=2)
        front = AsyncEngine(eng)
        busy = await front.submit(prompts[0], 6)
        await asyncio.sleep(0.05)
        lo = await front.submit(prompts[1], 4, priority=0)
        hi = await front.submit(prompts[2], 4, priority=9)
        r_lo, r_hi = await lo.result(), await hi.result()
        assert r_hi.ttft_steps > 0 and r_lo.ttft_steps > 0
        # the high-priority request reached a slot first
        assert eng._slot_meta == {} and _pool_empty(eng.pool)
        assert r_hi.ttft_s <= r_lo.ttft_s
        await front.close()
        await busy.result()

    asyncio.run(run())


def test_cancel_mid_stream_releases_pages(setup):
    """session.cancel() mid-stream aborts through Engine.abort: the stream
    ends, the Result carries the streamed prefix, co-residents keep their
    exact streams, and the pool drains empty."""
    cfg, params, prompts = setup
    ref = _drain_reference(cfg, params, prompts)

    async def run():
        eng = Engine(
            cfg, params, max_len=64, capacity=4, prefill_chunk=2, dispatch_depth=2
        )
        front = AsyncEngine(eng)
        sessions = [
            await front.submit(p, 8, sampling=SamplingSpec(seed=i))
            for i, p in enumerate(prompts)
        ]
        got = []
        async for t in sessions[1]:
            got.append(t)
            if len(got) == 3:
                sessions[1].cancel()
        r = await sessions[1].result()
        assert r.finish_reason == "aborted"
        assert tuple(r.tokens) == tuple(got)
        k = len(got)
        assert tuple(got) == ref[1][:k]  # prefix of the solo stream
        for i in (0, 2, 3):
            ri = await sessions[i].result()
            assert tuple(ri.tokens) == ref[i]
        await front.close()
        assert _pool_empty(eng.pool)

    asyncio.run(run())


def test_backpressure_wait_suspends_submit(setup):
    """submit(wait=True) against a full queue suspends instead of
    shedding, resuming when admission frees space."""
    cfg, params, prompts = setup

    async def run():
        eng = Engine(cfg, params, max_len=64, capacity=1, prefill_chunk=2)
        front = AsyncEngine(eng, max_queue=1)
        first = await front.submit(prompts[0], 4)
        await asyncio.sleep(0.05)
        second = await front.submit(prompts[1], 4)  # fills the queue
        t0 = asyncio.get_running_loop().time()
        third = await front.submit(prompts[2], 4, wait=True)
        assert asyncio.get_running_loop().time() >= t0  # resumed, not shed
        done = [await s.result() for s in (first, second, third)]
        assert all(r.finish_reason == "length" for r in done)
        await front.close()
        assert _pool_empty(eng.pool)

    asyncio.run(run())
