"""Per-arch smoke tests: every assigned architecture (reduced same-family
config) runs one forward + one train step on CPU; output shapes asserted,
no NaNs (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as S
from repro.models import model as M

ALL_ARCHS = list(configs.ARCHS) + ["bigbird-base"]


def smoke_batch(cfg, B=2, S_=128, key=jax.random.PRNGKey(0)):
    toks = jax.random.randint(key, (B, S_), 4, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.kind == "encdec":
        batch = {"frames": jax.random.normal(key, (B, S_, cfg.d_model)),
                 "tokens": jax.random.randint(key, (B, cfg.dec_len), 4,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(key, (B, cfg.dec_len), 4,
                                              cfg.vocab_size)}
    if cfg.frontend == "patch":
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_shapes(arch):
    cfg = configs.smoke(arch)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    logits = M.logits_fn(params, cfg, batch)
    exp_len = cfg.dec_len if cfg.kind == "encdec" else 128
    assert logits.shape == (2, exp_len, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"
    loss = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = configs.smoke(arch)
    opt = S.make_optimizer(kind=configs.optimizer_for(arch),
                           schedule="constant", peak_lr=1e-3)
    ts = jax.jit(S.make_train_step(cfg, opt, microbatches=1))
    params = M.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = smoke_batch(cfg)
    state, metrics = ts(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(state["params"])[0]
    assert float(jnp.abs(l0 - l1).max()) > 0


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    rows = {
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        cfg = configs.get(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    assert configs.get("rwkv6-7b").num_layers == 32
    assert configs.get("rwkv6-7b").d_model == 4096
    assert configs.get("rwkv6-7b").vocab_size == 65536


def test_moe_configs():
    g = configs.get("grok-1-314b")
    assert g.moe.num_experts == 8 and g.moe.top_k == 2
    l4 = configs.get("llama4-scout-17b-a16e")
    assert l4.moe.num_experts == 16 and l4.moe.top_k == 1
    j = configs.get("jamba-1.5-large-398b")
    assert j.moe.num_experts == 16 and j.moe.top_k == 2
    # jamba interleave: exactly 1 attention layer per 8
    kinds = [ls.kind for ls in j.layer_pattern]
    assert kinds.count("attn") == 1 and len(kinds) == 8


def test_gemma_local_global_ratio():
    g = configs.get("gemma3-4b")
    kinds = [("full" if (ls.attn is None or ls.attn.kind == "full") else "local")
             for ls in g.layer_pattern]
    assert len(kinds) == 34
    assert kinds.count("full") == 5 and kinds.count("local") == 29
    # every 6th layer is global
    for i, k in enumerate(kinds):
        assert (k == "full") == ((i + 1) % 6 == 0)


def test_param_counts_close_to_published():
    """Total params within 10% of the published totals (backbone-only for
    multimodal archs)."""
    from repro.models.params import param_count
    expected = {
        "minicpm-2b": 2.7e9, "yi-6b": 6.1e9, "h2o-danube-1.8b": 1.8e9,
        "grok-1-314b": 314e9, "jamba-1.5-large-398b": 398e9,
        "rwkv6-7b": 7.5e9, "gemma3-4b": 3.9e9,
    }
    for arch, n_exp in expected.items():
        n = param_count(M.param_spec(configs.get(arch)))
        assert abs(n - n_exp) / n_exp < 0.10, f"{arch}: {n/1e9:.2f}B"


def test_moe_aux_loss_nonzero_and_load_balances():
    cfg = configs.smoke("grok-1-314b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    h, aux = M.hidden_states(params, cfg, batch)
    assert float(aux) > 0.0
    # with random routing, aux ~ num_moe_layers * ~1.0 (balanced)
    n_moe = sum(1 for ls in cfg.layer_pattern if ls.moe) * cfg.repeats
    assert float(aux) < 4.0 * max(n_moe, 1)


def test_etc_vs_itc_variant():
    """bigbird_variant swaps full attention for the paper pattern."""
    from repro.configs import common
    cfg = configs.get("yi-6b")
    bb = common.bigbird_variant(cfg)
    assert bb.attn.kind == "bigbird"
    assert common.is_subquadratic(bb)
    assert not common.is_subquadratic(cfg)
    # rwkv is natively sub-quadratic
    assert common.is_subquadratic(configs.get("rwkv6-7b"))


def test_vocab_padding_preserves_loss_and_logits():
    """§Perf P8: padding the vocab to a shardable multiple must not change
    the loss (padded logits masked) or the argmax over real tokens."""
    import jax
    import jax.numpy as jnp
    cfg0 = configs.smoke("yi-6b")                     # vocab 512
    cfg1 = dataclasses.replace(cfg0, vocab_pad=96)    # padded_vocab 576
    assert cfg1.padded_vocab == 576
    key = jax.random.PRNGKey(0)
    p1 = M.init(cfg1, key)
    # copy the shared slice into an unpadded model's params
    p0 = M.init(cfg0, key)
    p0["embed"]["table"] = p1["embed"]["table"][:512]
    if "unembed" in p1:
        p0["unembed"]["w"] = p1["unembed"]["w"][..., :512]
    for k in ("layers", "final_norm"):
        p0[k] = p1[k]
    batch = smoke_batch(cfg0)
    l0 = M.loss_fn(p0, cfg0, batch)
    l1 = M.loss_fn(p1, cfg1, batch)
    assert abs(float(l0) - float(l1)) < 2e-3, (float(l0), float(l1))
    g0 = M.logits_fn(p0, cfg0, batch)
    g1 = M.logits_fn(p1, cfg1, batch)
    assert g1.shape == g0.shape                        # sliced to real vocab
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=1e-3)
