"""Mesh-parallel serving: the sharded bit-identity contract and the
per-data-shard PagePool invariants (DESIGN.md §Mesh-parallel serving).

These run in the CI multi-device job under
XLA_FLAGS=--xla_force_host_platform_device_count=8 and self-skip when the
process has fewer devices than a mesh needs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec
from repro.dist import sharding as Sh
from repro.models import model as M
from repro.serve import Engine, Request, SamplingSpec

try:
    from _prop import given, settings, st
except ImportError:
    from tests._prop import given, settings, st

KEY = jax.random.PRNGKey(0)
MESHES = ((1, 1), (2, 1), (1, 2), (2, 2))

pytestmark = pytest.mark.multidevice


def _need(n):
    if len(jax.devices()) < n:
        pytest.skip(
            f"needs {n} devices (have {len(jax.devices())}); run with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )


def _mesh(d, m):
    _need(d * m)
    from repro.serve import mesh as Mx

    return Mx.make_mesh(d, m)


def _cfg(impl="blockified", kv_heads=2, scan=False, layers=2):
    bb = AttentionSpec(
        kind="bigbird",
        causal=True,
        block_size=8,
        num_window_blocks=3,
        num_global_blocks=1,
        num_random_blocks=1,
        impl=impl,
    )
    return M.ModelConfig(
        name="mesh-test",
        d_model=32,
        num_layers=layers,
        num_heads=4,
        num_kv_heads=kv_heads,
        d_ff=64,
        vocab_size=128,
        attn=bb,
        dtype=jnp.float32,
        scan_layers=scan,
        remat="none",
        loss_chunk=32,
        max_seq=256,
    )


def _serve(cfg, params, prompts, mesh=None, capacity=4, max_new=8):
    eng = Engine(
        cfg, params, max_len=64, capacity=capacity, prefill_chunk=2, mesh=mesh
    )
    for i, p in enumerate(prompts):
        spec = SamplingSpec(temperature=0.8, top_k=20, seed=i)
        eng.submit(Request(prompt=p, max_new_tokens=max_new, sampling=spec))
    return [r.tokens for r in eng.drain()], eng


# --------------------------------------------------------------------------
# sharded bit-identity across mesh shapes
# --------------------------------------------------------------------------


@given(dxm=st.sampled_from(MESHES), seed=st.integers(0, 3))
@settings(max_examples=12, deadline=None)
def test_sharded_decode_bit_identical_to_replicated(dxm, seed):
    """Property: for every mesh shape and prompt set, the sharded engine
    emits exactly the replicated engine's token streams."""
    d, m = dxm
    _need(d * m)
    cfg = _cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(4, cfg.vocab_size, size=int(n)).astype(np.int32)
        for n in rng.integers(9, 40, size=4)
    ]
    ref, _ = _serve(cfg, params, prompts, mesh=None)
    got, _ = _serve(cfg, params, prompts, mesh=_mesh(d, m))
    assert got == ref, (d, m)


def test_sharded_gqa_and_scanned_and_pallas():
    """The head-slice contract holds for GQA splits down to one kv head
    per model shard, for scanned stacks, and for the Pallas paged-decode
    kernel running per shard."""
    for name, cfg in (
        ("gqa", _cfg(kv_heads=2)),
        ("scan", _cfg(kv_heads=2, scan=True, layers=4)),
        ("pallas", _cfg(impl="pallas")),
    ):
        params = M.init(cfg, KEY)
        rng = np.random.default_rng(7)
        prompts = [
            rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)
            for n in (19, 33, 11, 26)
        ]
        ref, _ = _serve(cfg, params, prompts, mesh=None)
        got, _ = _serve(cfg, params, prompts, mesh=_mesh(2, 2))
        assert got == ref, name


def test_sharded_staggered_admission_matches_solo():
    """Stagger requests across engine steps on a 2x1 mesh: every stream
    must still match its solo (replicated, sole-resident) run."""
    cfg = _cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)
        for n in (19, 33, 11, 26)
    ]

    def req(i):
        return Request(
            prompt=prompts[i],
            max_new_tokens=10,
            sampling=SamplingSpec(temperature=0.8, top_k=20, seed=i),
        )

    solo = []
    for i in range(4):
        eng = Engine(cfg, params, max_len=64, capacity=4, prefill_chunk=2)
        eng.submit(req(i))
        solo.append(eng.drain()[0].tokens)

    eng = Engine(
        cfg, params, max_len=64, capacity=4, prefill_chunk=2, mesh=_mesh(2, 1)
    )
    eng.submit(req(0))
    eng.step()
    eng.submit(req(1))
    eng.submit(req(2))
    eng.step()
    eng.submit(req(3))
    results = eng.drain()
    assert [r.request_id for r in results] == [0, 1, 2, 3]
    for r, expect in zip(results, solo):
        assert r.tokens == expect, r.request_id


# --------------------------------------------------------------------------
# per-shard PagePool invariants
# --------------------------------------------------------------------------


def _assert_pool_invariants(pool):
    """Refcount/ownership invariants that must hold per data shard."""
    pps = pool.pages_per_shard
    for slot, s in enumerate(pool.slots):
        if s is None:
            continue
        shard = pool.slot_shard(slot)
        for pg in s.pages:
            assert pool.page_shard(pg) == shard, (slot, pg)
            assert pool.refcount[pg] >= 1
        live = pool.page_tables[slot, : len(s.pages)]
        assert all(pool.page_shard(int(p)) == shard for p in live)
    for d in range(pool.data_shards):
        assert pool.refcount[d * pps] == 0  # dump pages are never refcounted
        for pg in pool._free[d]:
            assert pool.page_shard(pg) == d
            assert pool.refcount[pg] == 0


@given(dxm=st.sampled_from(((1, 1), (2, 1), (2, 2))), seed=st.integers(0, 2))
@settings(max_examples=9, deadline=None)
def test_pool_refcount_invariants_per_shard(dxm, seed):
    """Property: mid-flight and after drain, every slot's pages live in
    its own shard's sub-pool, refcounts are consistent, and eviction
    returns pages to the owning shard's free list."""
    d, m = dxm
    _need(d * m)
    cfg = _cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(seed)
    # a shared one-page prefix makes prefix pages shard-locally refcounted
    prefix = rng.integers(4, cfg.vocab_size, size=8).astype(np.int32)
    tails = [
        rng.integers(4, cfg.vocab_size, size=int(n)).astype(np.int32)
        for n in rng.integers(9, 30, size=6)
    ]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    mesh = _mesh(d, m) if (d, m) != (1, 1) else None
    eng = Engine(cfg, params, max_len=64, capacity=4, prefill_chunk=2, mesh=mesh)
    for i, p in enumerate(prompts):
        samp = SamplingSpec(seed=i)
        eng.submit(Request(prompt=p, max_new_tokens=4 + 2 * (i % 3), sampling=samp))
    while eng._queue or eng.pool.active_slots():
        eng.step()
        _assert_pool_invariants(eng.pool)
    assert eng.pool.pages_in_use == 0
    free_total = sum(len(f) for f in eng.pool._free)
    assert free_total == eng.pool.num_pages - eng.pool.data_shards


def test_cow_copy_stays_in_shard():
    """The copy-on-write guard allocates the private copy from the
    writer's own shard's free list."""
    cfg = _cfg()
    params = M.init(cfg, KEY)
    eng = Engine(
        cfg, params, max_len=64, capacity=4, prefill_chunk=2, mesh=_mesh(2, 1)
    )
    pool = eng.pool
    rng = np.random.default_rng(9)
    for i in range(4):  # slots 0,1 -> shard 0; slots 2,3 -> shard 1
        prompt = rng.integers(4, cfg.vocab_size, size=12).astype(np.int32)
        samp = SamplingSpec(seed=i)
        eng.submit(Request(prompt=prompt, max_new_tokens=10, sampling=samp))
    while pool.prefill_slots() or eng._queue:
        eng.step()
    slot = pool.cap_local  # first slot of shard 1
    s = pool.slots[slot]
    peer = pool.slots[slot + 1]
    # force an artificial intra-shard share, then trigger the guard
    old = s.pages[0]
    alias = peer.pages[0]
    pool.refcount[old] -= 1
    pool._free[1].append(old)
    s.pages[0] = alias
    pool.refcount[alias] += 1
    pool.page_tables[slot, 0] = alias
    assert pool.ensure_writable(slot, 0) is True
    new = s.pages[0]
    assert new != alias and pool.page_shard(new) == 1
    assert pool.refcount[alias] == 1 and pool.refcount[new] == 1
    _assert_pool_invariants(pool)


def test_page_exhaustion_queues_per_shard():
    """One shard's sub-pool running dry must not block the other shard;
    the starved shard's requests wait and still complete."""
    cfg = _cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(6)
    # per shard: 5 usable pages; each request needs 4 -> one resident per
    # shard at a time, remaining requests queue
    eng = Engine(
        cfg,
        params,
        max_len=64,
        capacity=4,
        prefill_chunk=2,
        num_pages=12,
        mesh=_mesh(2, 1),
    )
    for i in range(4):
        prompt = rng.integers(4, cfg.vocab_size, size=24).astype(np.int32)
        samp = SamplingSpec(seed=i)
        eng.submit(Request(prompt=prompt, max_new_tokens=8, sampling=samp))
    results = eng.drain()
    assert [r.request_id for r in results] == [0, 1, 2, 3]
    assert all(len(r.tokens) == 8 for r in results)
    assert max(eng.pool.peak_pages_per_shard) <= 5


# --------------------------------------------------------------------------
# validation and stats partitioning
# --------------------------------------------------------------------------


def test_validate_serving_mesh_rejects_bad_shapes():
    cfg = _cfg(kv_heads=2)
    mesh = _mesh(1, 4)  # model=4 does not divide num_kv_heads=2
    with pytest.raises(ValueError, match="num_kv_heads"):
        Sh.validate_serving_mesh(cfg, mesh, capacity=4)
    mesh = _mesh(3, 1)  # data=3 does not divide capacity=4
    with pytest.raises(ValueError, match="capacity"):
        Sh.validate_serving_mesh(cfg, mesh, capacity=4)
    mesh = _mesh(2, 1)
    with pytest.raises(ValueError, match="num_pages"):
        Sh.validate_serving_mesh(cfg, mesh, capacity=4, num_pages=7)


def test_mesh_requires_chunked_prefill_config():
    cfg = _cfg()
    params = M.init(cfg, KEY)
    with pytest.raises(ValueError, match="chunked-prefill"):
        Engine(
            cfg, params, max_len=64, capacity=4, prefill_chunk=None, mesh=_mesh(2, 1)
        )


def test_pool_stats_partitioned_per_shard():
    cfg = _cfg()
    params = M.init(cfg, KEY)
    prompts = [np.arange(4, 24 + 4 * i, dtype=np.int32) % 100 + 4 for i in range(4)]
    _, eng = _serve(cfg, params, prompts, mesh=_mesh(2, 1))
    st_ = eng.stats()
    assert st_.data_shards == 2
    assert len(st_.pages_in_use_per_shard) == 2
    assert len(st_.peak_pages_per_shard) == 2
    assert st_.num_pages == 2 * st_.pages_per_shard
    assert sum(st_.pages_in_use_per_shard) == st_.pages_in_use == 0
    assert st_.kv_bytes_per_shard > 0
    # both shards admitted work (2 slots each, 4 requests)
    assert all(p > 0 for p in st_.peak_pages_per_shard)
