"""Gradient-parity sweep — the trainability contract of every attention impl.

`jax.grad` of a scalar loss through `attention(...)` must agree across
impl in {reference, blockified, pallas} (the pallas backward is a set of
custom_vjp Pallas kernels, see kernels/ops.py) for causal/non-causal, GQA,
the non-block-multiple padding path, and bf16 inputs.  Plus the end-to-end
acceptance check: jax.value_and_grad of a training loss with impl="pallas"
runs under jit and matches the blockified path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec, attention

RNG = np.random.default_rng(7)


def qkv(B, Hq, Hkv, S, d, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((B, Hq, S, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, d)), dtype)
    cot = jnp.asarray(RNG.standard_normal((B, Hq, S, d)), dtype)
    return q, k, v, cot


def grads_of(spec, impl, q, k, v, cot, use_jit=False):
    spec = dataclasses.replace(spec, impl=impl)

    def loss(q, k, v):
        out = attention(q, k, v, spec)
        return jnp.sum((out * cot).astype(jnp.float32))

    g = jax.grad(loss, argnums=(0, 1, 2))
    return (jax.jit(g) if use_jit else g)(q, k, v)


def assert_tree_close(ga, gb, atol, rtol):
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=atol, rtol=rtol)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("B,Hq,Hkv,S,d,b,w,g,r", [
    (1, 2, 2, 256, 16, 16, 3, 2, 2),     # base pattern
    (2, 4, 2, 256, 16, 16, 3, 1, 2),     # GQA: Hq > Hkv
    (1, 2, 1, 256, 32, 16, 3, 0, 2),     # no global (window+random), GQA
    (1, 2, 2, 384, 16, 16, 5, 2, 0),     # no random
])
def test_grad_parity_sweep(causal, B, Hq, Hkv, S, d, b, w, g, r):
    spec = AttentionSpec(kind="bigbird", causal=causal, block_size=b,
                         num_window_blocks=w, num_global_blocks=g,
                         num_random_blocks=r)
    q, k, v, cot = qkv(B, Hq, Hkv, S, d)
    gb = grads_of(spec, "blockified", q, k, v, cot)
    gp = grads_of(spec, "pallas", q, k, v, cot, use_jit=True)
    gr = grads_of(spec, "reference", q, k, v, cot)
    assert_tree_close(gp, gb, atol=1e-4, rtol=1e-4)
    assert_tree_close(gr, gb, atol=1e-4, rtol=1e-4)


def test_grad_parity_padding_path():
    """Non-block-multiple S (causal): grads flow through the pad/slice."""
    spec = AttentionSpec(kind="bigbird", causal=True, block_size=16,
                         num_window_blocks=3, num_global_blocks=2,
                         num_random_blocks=2)
    q, k, v, cot = qkv(1, 2, 2, 200, 16)       # 200 = 12*16 + 8
    gb = grads_of(spec, "blockified", q, k, v, cot)
    gp = grads_of(spec, "pallas", q, k, v, cot)
    assert_tree_close(gp, gb, atol=1e-4, rtol=1e-4)


def test_grad_parity_window_kind():
    """SWA expressed as the BigBird window component (kind="window")."""
    spec = AttentionSpec(kind="window", causal=True, block_size=32,
                         window_tokens=96)
    q, k, v, cot = qkv(1, 2, 2, 512, 16)
    gb = grads_of(spec, "blockified", q, k, v, cot)
    gp = grads_of(spec, "pallas", q, k, v, cot)
    assert_tree_close(gp, gb, atol=1e-4, rtol=1e-4)


def test_grad_parity_bf16():
    """bf16 inputs: compare in fp32 with bf16-resolution tolerances."""
    spec = AttentionSpec(kind="bigbird", causal=True, block_size=16,
                         num_window_blocks=3, num_global_blocks=1,
                         num_random_blocks=1)
    q, k, v, cot = qkv(1, 2, 2, 256, 16, dtype=jnp.bfloat16)
    gb = grads_of(spec, "blockified", q, k, v, cot)
    gp = grads_of(spec, "pallas", q, k, v, cot)
    for a, b in zip(gp, gb):
        assert a.dtype == jnp.bfloat16
        assert not bool(jnp.isnan(a.astype(jnp.float32)).any())
    assert_tree_close(gp, gb, atol=4e-2, rtol=4e-2)


def test_grad_pallas_fully_masked_rows_are_zero():
    """Rows with no live key (r-only causal pattern, early rows) must get
    zero gradient, not NaN (the lse sentinel path)."""
    from repro.core import patterns
    from repro.kernels import ops
    cfg = patterns.BigBirdConfig(block_size=16, num_window_blocks=1,
                                 num_global_blocks=0, num_random_blocks=2,
                                 causal=True)
    q, k, v, cot = qkv(1, 2, 2, 256, 16)

    def loss(q, k, v):
        return jnp.sum(ops.bigbird_attention_fused(q, k, v, cfg) * cot)

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert not bool(jnp.isnan(g).any())


def test_training_loss_value_and_grad_pallas_under_jit():
    """Acceptance: jax.value_and_grad of a training loss with impl="pallas"
    runs under jit and matches the blockified path."""
    from repro import configs
    from repro.configs.common import with_attn_impl
    from repro.models import model as M

    cfg_p = configs.smoke("bigbird-base")
    assert cfg_p.attn.impl == "pallas"         # pallas is the default path
    cfg_b = with_attn_impl(cfg_p, "blockified")

    toks = jnp.asarray(RNG.integers(4, cfg_p.vocab_size, (2, 128)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    params = M.init(cfg_p, jax.random.PRNGKey(0))

    results = {}
    for name, cfg in (("pallas", cfg_p), ("blockified", cfg_b)):
        vg = jax.jit(jax.value_and_grad(
            lambda p, c=cfg: M.loss_fn(p, c, batch)))
        loss, grads = vg(params)
        assert np.isfinite(float(loss))
        results[name] = (float(loss), grads)

    lp, gp = results["pallas"]
    lb, gb = results["blockified"]
    assert abs(lp - lb) < 1e-4, (lp, lb)
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)
