"""Generation Engine: sampling invariants, jitted-loop equivalence with the
hand-rolled greedy decode, and slot-batched continuous serving producing
bit-identical streams under staggered admission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionSpec
from repro.models import decode as D
from repro.models import model as M
from repro.serve import Engine, Request, SamplingSpec
from repro.serve import sampling as Smp

KEY = jax.random.PRNGKey(0)


def _small_cfg(vocab=128, max_seq=256):
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    return M.ModelConfig(name="serve-test", d_model=32, num_layers=2,
                         num_heads=4, num_kv_heads=4, d_ff=64,
                         vocab_size=vocab, attn=bb, dtype=jnp.float32,
                         scan_layers=False, remat="none", loss_chunk=32,
                         max_seq=max_seq)


def _rand_logits(B=4, V=64, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((B, V)),
                       jnp.float32)


def _samp(B, **kw):
    return Smp.uniform_spec_arrays(SamplingSpec(**kw), B)


# --------------------------------------------------------------------------
# sampling invariants
# --------------------------------------------------------------------------

def test_temperature_zero_is_greedy():
    logits = _rand_logits()
    s = _samp(4, temperature=0.0)
    out = Smp.sample_tokens(logits, s["keys"], s["temperature"], s["top_k"],
                            s["top_p"])
    np.testing.assert_array_equal(out, jnp.argmax(logits, -1))


def test_temperature_to_zero_limit_is_greedy():
    """top-1 at temp -> 0 equals greedy: logits/T dwarf the Gumbel noise."""
    logits = _rand_logits(seed=1)
    s = _samp(4, temperature=1e-5)
    out = Smp.sample_tokens(logits, s["keys"], s["temperature"], s["top_k"],
                            s["top_p"])
    np.testing.assert_array_equal(out, jnp.argmax(logits, -1))


def test_topk_full_vocab_equals_plain_sampling():
    """top_k = V must be bit-identical to top_k disabled (same keys)."""
    logits = _rand_logits(B=8, seed=2)
    V = logits.shape[-1]
    plain = _samp(8, temperature=1.0, top_k=0)
    full = _samp(8, temperature=1.0, top_k=V)
    a = Smp.sample_tokens(logits, plain["keys"], plain["temperature"],
                          plain["top_k"], plain["top_p"])
    b = Smp.sample_tokens(logits, full["keys"], full["temperature"],
                          full["top_k"], full["top_p"])
    np.testing.assert_array_equal(a, b)


def test_top_p_one_equals_plain_sampling():
    logits = _rand_logits(B=8, seed=3)
    plain = _samp(8, temperature=0.7)
    explicit = _samp(8, temperature=0.7, top_p=1.0)
    a = Smp.sample_tokens(logits, plain["keys"], plain["temperature"],
                          plain["top_k"], plain["top_p"])
    b = Smp.sample_tokens(logits, explicit["keys"], explicit["temperature"],
                          explicit["top_k"], explicit["top_p"])
    np.testing.assert_array_equal(a, b)


def test_topk_restricts_support():
    """Sampled ids must come from each row's top-k logits."""
    logits = _rand_logits(B=16, V=64, seed=4)
    k = 5
    s = _samp(16, temperature=1.5, top_k=k)
    topsets = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    for trial in range(10):
        keys = Smp.fold_step_keys(s["keys"], trial)
        out = np.asarray(Smp.sample_tokens(
            logits, keys, s["temperature"], s["top_k"], s["top_p"]))
        for i in range(16):
            assert out[i] in topsets[i]


def test_top_p_keeps_at_least_top1_and_respects_nucleus():
    # one spiky row (nucleus = single token) + one flat row
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0], [1.0, 1.0, 1.0, 1.0]],
                         jnp.float32)
    s = _samp(2, temperature=1.0, top_p=0.5)
    for trial in range(10):
        keys = Smp.fold_step_keys(s["keys"], trial)
        out = np.asarray(Smp.sample_tokens(
            logits, keys, s["temperature"], s["top_k"], s["top_p"]))
        assert out[0] == 0           # spiky row: nucleus is exactly top-1
        assert 0 <= out[1] < 4


def test_top_k_one_equals_greedy():
    """top_k=1 at any temperature leaves only the argmax in the keep set
    — bit-identical to greedy (the degenerate edge rejection sampling
    leans on: a point-mass truncated distribution)."""
    logits = _rand_logits(B=8, seed=11)
    s = _samp(8, temperature=1.3, top_k=1)
    for trial in range(5):
        keys = Smp.fold_step_keys(s["keys"], trial)
        out = Smp.sample_tokens(logits, keys, s["temperature"], s["top_k"],
                                s["top_p"])
        np.testing.assert_array_equal(out, jnp.argmax(logits, -1))


def test_deterministic_tie_breaking_under_fixed_keys():
    """Exactly tied logits: greedy must take the lowest index (argmax
    tie rule), and sampling with a fixed key must repeat the same pick
    call after call — no hidden nondeterminism for rejection sampling to
    diverge on."""
    logits = jnp.zeros((4, 16), jnp.float32).at[:, 3].set(1.0).at[:, 9].set(1.0)
    g = _samp(4, temperature=0.0)
    out = Smp.sample_tokens(logits, g["keys"], g["temperature"], g["top_k"],
                            g["top_p"])
    np.testing.assert_array_equal(out, np.full(4, 3))    # first max wins
    s = _samp(4, temperature=1.0, top_k=2)
    draws = [np.asarray(Smp.sample_tokens(
        logits, Smp.fold_step_keys(s["keys"], 7), s["temperature"],
        s["top_k"], s["top_p"])) for _ in range(5)]
    for d in draws[1:]:
        np.testing.assert_array_equal(d, draws[0])
    assert set(np.concatenate(draws).tolist()) <= {3, 9}


def test_truncated_probs_supports_device_sampler():
    """The host mirror of the truncation rule (what speculative
    acceptance integrates against) must carry exactly the device
    sampler's support: every sampled token has positive mirrored
    probability, zero-probability tokens are never drawn."""
    logits = _rand_logits(B=1, V=64, seed=13)
    spec = SamplingSpec(temperature=0.7, top_k=9, top_p=0.8, seed=5)
    p = Smp.truncated_probs(np.asarray(logits[0]), spec)
    assert abs(p.sum() - 1.0) < 1e-9 and (p >= 0).all()
    assert int((p > 0).sum()) <= 9
    s = Smp.spec_arrays([spec])
    for trial in range(25):
        keys = Smp.fold_step_keys(s["keys"], trial)
        tok = int(Smp.sample_tokens(logits, keys, s["temperature"],
                                    s["top_k"], s["top_p"])[0])
        assert p[tok] > 0.0, tok


def test_per_row_seeds_differ():
    """Per-request seeds: identical rows sample different streams."""
    logits = jnp.tile(_rand_logits(B=1, V=64, seed=5), (8, 1))
    s = _samp(8, temperature=1.0, seed=9)
    draws = [np.asarray(Smp.sample_tokens(
        logits, Smp.fold_step_keys(s["keys"], t), s["temperature"],
        s["top_k"], s["top_p"])) for t in range(6)]
    streams = np.stack(draws, 1)          # (8 rows, 6 steps)
    assert len({tuple(r) for r in streams.tolist()}) > 1


# --------------------------------------------------------------------------
# Engine.generate vs hand-rolled greedy decode
# --------------------------------------------------------------------------

def test_generate_matches_hand_rolled_greedy():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    B, L, N, MAXLEN = 2, 16, 12, 64     # L == its bucket -> no padding
    prompts = jax.random.randint(KEY, (B, L), 4, cfg.vocab_size)

    engine = Engine(cfg, params, max_len=MAXLEN, capacity=B)
    out = engine.generate([p for p in prompts], max_new=N)

    logits, cache = D.prefill(params, cfg, {"tokens": prompts}, MAXLEN)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    ref = [tok]
    for i in range(N - 1):
        logits, cache = D.decode_step(params, cfg, cache, tok, L + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        ref.append(tok)
    np.testing.assert_array_equal(out.tokens, jnp.concatenate(ref, axis=1))


def test_generate_bucketed_padding_is_exact():
    """Right-padded (bucketed) prefill must equal exact-length prefill."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    engine = Engine(cfg, params, max_len=64, capacity=2)
    prompt = np.asarray(
        jax.random.randint(KEY, (13,), 4, cfg.vocab_size))   # bucket -> 16
    a = engine.generate([prompt], max_new=8)

    logits, cache = D.prefill(params, cfg, {"tokens": jnp.asarray(prompt)[None]},
                              64)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    ref = [int(tok[0, 0])]
    for i in range(7):
        logits, cache = D.decode_step(params, cfg, cache, tok, 13 + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        ref.append(int(tok[0, 0]))
    assert a.tokens[0].tolist() == ref


def test_generate_stop_token():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    engine = Engine(cfg, params, max_len=64, capacity=1)
    prompt = np.asarray(jax.random.randint(KEY, (16,), 4, cfg.vocab_size))
    free_run = engine.generate([prompt], max_new=8)
    stop = int(free_run.tokens[0, 2])       # 3rd greedy token as "EOS"
    out = engine.generate([prompt], max_new=8, stop_token=stop)
    n = int(out.lengths[0])
    assert n <= 3 and out.tokens[0, n - 1] == stop
    assert (out.tokens[0, n:] == 0).all()   # post-stop positions padded


# --------------------------------------------------------------------------
# continuous batching
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_staggered_requests_bit_identical_to_solo():
    """Requests admitted mid-flight (heterogeneous prompt lengths and
    positions) must produce exactly the tokens a solo run produces."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, cfg.vocab_size, size=l).astype(np.int32)
               for l in (19, 33, 11)]

    def make_reqs():
        return [Request(prompt=p, max_new_tokens=10,
                        sampling=SamplingSpec(temperature=0.8, top_k=20,
                                              seed=i))
                for i, p in enumerate(prompts)]

    solo = []
    for r in make_reqs():
        eng = Engine(cfg, params, max_len=64, capacity=3)
        eng.submit(r)
        solo.append(eng.drain()[0].tokens)

    eng = Engine(cfg, params, max_len=64, capacity=3)
    reqs = make_reqs()
    eng.submit(reqs[0])
    eng.step()                       # req0 alone in flight
    eng.step()
    eng.submit(reqs[1])
    eng.step()                       # req1 joins three steps late
    eng.submit(reqs[2])
    results = eng.drain()            # req2 joins later still
    assert [r.request_id for r in results] == [0, 1, 2]
    for r, expect in zip(results, solo):
        assert r.tokens == expect, (r.request_id, r.tokens, expect)


def test_oversubscribed_queue_reuses_slots():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, max_len=64, capacity=2)
    for i in range(5):               # 5 requests through 2 slots
        eng.submit(Request(
            prompt=rng.integers(4, cfg.vocab_size, size=8 + i).astype(np.int32),
            max_new_tokens=4, sampling=SamplingSpec(seed=i)))
    results = eng.drain()
    assert [r.request_id for r in results] == [0, 1, 2, 3, 4]
    assert all(len(r.tokens) == 4 and r.finish_reason == "length"
               for r in results)


def test_slot_stop_token_finishes_early():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    prompt = np.asarray(jax.random.randint(KEY, (16,), 4, cfg.vocab_size))
    eng = Engine(cfg, params, max_len=64, capacity=1)
    eng.submit(Request(prompt=prompt, max_new_tokens=8))
    free_run = eng.drain()[0]
    stop = free_run.tokens[2]
    eng.submit(Request(prompt=prompt, max_new_tokens=8, stop_token=stop))
    res = eng.drain()[0]
    assert res.finish_reason == "stop"
    assert res.tokens == free_run.tokens[:3]


# --------------------------------------------------------------------------
# paged pool: chunked prefill, prefix sharing, CoW, bucketed max_new
# --------------------------------------------------------------------------

def test_generate_max_new_bucketing_shares_executable():
    """max_new values in one power-of-two bucket share one compiled loop,
    and greedy streams agree on the common prefix (the traced `limit` only
    stops the loop early)."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    engine = Engine(cfg, params, max_len=64, capacity=1)
    prompt = np.asarray(jax.random.randint(KEY, (16,), 4, cfg.vocab_size))
    a = engine.generate([prompt], max_new=5)
    b = engine.generate([prompt], max_new=12)
    assert len(engine._generate) == 1          # both hit the 16 bucket
    assert a.tokens.shape[1] == 5 and b.tokens.shape[1] == 12
    np.testing.assert_array_equal(a.tokens[0], b.tokens[0, :5])
    c = engine.generate([prompt], max_new=20)  # new bucket: 32
    assert len(engine._generate) == 2
    np.testing.assert_array_equal(b.tokens[0], c.tokens[0, :12])


def test_chunked_prefill_engine_matches_one_shot_engine():
    """Streams from a chunked-prefill engine must equal the one-shot-admit
    engine token for token (chunked prefill is exact, not approximate)."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    reqs = lambda: [
        Request(prompt=rng1.integers(4, cfg.vocab_size, size=l)
                .astype(np.int32),
                max_new_tokens=6, sampling=SamplingSpec(seed=i))
        for i, (rng1, l) in enumerate(
            [(np.random.default_rng(s), l) for s, l in
             ((1, 19), (2, 40), (3, 11))])]
    one = Engine(cfg, params, max_len=64, capacity=3, prefill_chunk=None)
    chk = Engine(cfg, params, max_len=64, capacity=3, prefill_chunk=2)
    assert not one._chunked and chk._chunked
    for r in reqs():
        one.submit(r)
    for r in reqs():
        chk.submit(r)
    a, b = one.drain(), chk.drain()
    for ra, rb in zip(a, b):
        assert ra.tokens == rb.tokens, (ra.request_id, ra.tokens, rb.tokens)


def test_shared_prefix_refcount_lifecycle():
    """Co-resident requests with a common prompt prefix share the global-
    prefix page (admitted once, refcount 2); evicting one sharer keeps the
    page alive for the other, whose stream stays solo-identical; draining
    everything returns every page to the free list."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(4)
    prefix = rng.integers(4, cfg.vocab_size, size=8).astype(np.int32)  # 1 page
    tails = [rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)
             for n in (20, 24)]
    prompts = [np.concatenate([prefix, t]) for t in tails]

    solo = []
    for i, p in enumerate(prompts):
        eng = Engine(cfg, params, max_len=64, capacity=2)
        eng.submit(Request(prompt=p, max_new_tokens=12,
                           sampling=SamplingSpec(seed=i)))
        solo.append(eng.drain()[0].tokens)

    eng = Engine(cfg, params, max_len=64, capacity=2)
    eng.submit(Request(prompt=prompts[0], max_new_tokens=6,
                       sampling=SamplingSpec(seed=0)))
    eng.step(); eng.step()                    # req0 resident, prefix indexed
    eng.submit(Request(prompt=prompts[1], max_new_tokens=12,
                       sampling=SamplingSpec(seed=1)))
    eng.step()
    s1 = eng.pool.slots[1]
    assert s1 is not None and s1.shared_pages == 1
    shared_pg = s1.pages[0]
    assert eng.pool.refcount[shared_pg] == 2  # both sharers still resident
    assert eng.pool.prefix_hits == 1
    results = {r.request_id: r for r in eng.drain()}
    # req0 (max_new=6) finished and was evicted first; the shared page must
    # have survived for req1, whose stream matches its solo run exactly
    assert results[1].tokens == solo[1]
    assert results[1].shared_prefix_pages == 1
    assert results[0].tokens == solo[0][:6]
    assert eng.pool.refcount[shared_pg] == 0
    free_total = sum(len(f) for f in eng.pool._free)
    assert free_total == eng.pool.num_pages - 1            # all returned
    assert not eng.pool._prefix and not eng.pool._page_key


def test_copy_on_write_guard():
    """A write aimed at a page with refcount > 1 must move the writer onto
    a private copy with identical contents (sharers unaffected)."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    eng = Engine(cfg, params, max_len=64, capacity=2)
    rng = np.random.default_rng(9)
    for i in range(2):
        eng.submit(Request(prompt=rng.integers(4, cfg.vocab_size, size=12)
                           .astype(np.int32),
                           max_new_tokens=10, sampling=SamplingSpec(seed=i)))
    eng.step()
    pool = eng.pool
    # force slot1's first page to alias slot0's first page (artificial share)
    old = pool.slots[1].pages[0]
    alias = pool.slots[0].pages[0]
    pool.refcount[old] -= 1
    pool._free[0].append(old)
    pool.slots[1].pages[0] = alias
    pool.refcount[alias] += 1
    pool.page_tables[1, 0] = alias
    before = np.asarray(pool.cache["layer0"]["k"][alias])
    assert pool.ensure_writable(1, 0) is True
    new = pool.slots[1].pages[0]
    assert new != alias and pool.refcount[alias] == 1
    assert pool.refcount[new] == 1 and pool.page_tables[1, 0] == new
    np.testing.assert_array_equal(
        np.asarray(pool.cache["layer0"]["k"][new]), before)
    assert pool.ensure_writable(1, 0) is False   # already private


def test_page_exhaustion_queues_requests():
    """A pool smaller than the working set serializes admissions instead of
    failing; every request still completes with full-length output."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(6)
    # each request needs ceil((24+8-1)/8)=4 pages; give the pool 5 usable
    eng = Engine(cfg, params, max_len=64, capacity=3, num_pages=6)
    for i in range(3):
        eng.submit(Request(prompt=rng.integers(4, cfg.vocab_size, size=24)
                           .astype(np.int32),
                           max_new_tokens=8, sampling=SamplingSpec(seed=i)))
    results = eng.drain()
    assert [r.request_id for r in results] == [0, 1, 2]
    assert all(len(r.tokens) == 8 for r in results)
    assert eng.pool.peak_pages_in_use <= 5


def test_scanned_config_paged_serving():
    """Scanned stacks (repeats > 1) page their (repeats, P, H, b, dh)
    leaves through the same tables; chunked == one-shot there too."""
    bb = AttentionSpec(kind="bigbird", causal=True, block_size=8,
                       num_window_blocks=3, num_global_blocks=1,
                       num_random_blocks=1)
    cfg = M.ModelConfig(name="scan-serve", d_model=32, num_layers=4,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                        attn=bb, dtype=jnp.float32, scan_layers=True,
                        remat="none", loss_chunk=32, max_seq=256)
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 128, size=l).astype(np.int32) for l in (19, 33)]

    def run(chunk):
        eng = Engine(cfg, params, max_len=64, capacity=2,
                     prefill_chunk=chunk)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=p, max_new_tokens=6,
                               sampling=SamplingSpec(seed=i)))
        return [r.tokens for r in eng.drain()]

    assert run(None) == run(2)


def test_final_chunk_clamped_at_logical_cache_end():
    """A near-max_len prompt whose last chunk would cross max_pages must be
    served by a clamped final chunk, not crash (and still match one-shot)."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    # max_len 40 -> 5 pages; chunk 4 blocks = 32 tokens; 36-token prompt:
    # second chunk would cover blocks 4..7 but the table ends at block 5
    prompt = np.asarray(jax.random.randint(KEY, (36,), 4, cfg.vocab_size))
    chk = Engine(cfg, params, max_len=40, capacity=1, prefill_chunk=4)
    chk.submit(Request(prompt=prompt, max_new_tokens=4,
                       sampling=SamplingSpec(seed=0)))
    got = chk.drain()[0].tokens
    one = Engine(cfg, params, max_len=40, capacity=1, prefill_chunk=None)
    one.submit(Request(prompt=prompt, max_new_tokens=4,
                       sampling=SamplingSpec(seed=0)))
    assert got == one.drain()[0].tokens


# --------------------------------------------------------------------------
# ragged multi-prompt prefill + pipelined decode dispatch
# --------------------------------------------------------------------------

def _mixed_requests(cfg, lens=(19, 40, 33, 11), max_new=8):
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, cfg.vocab_size, size=l).astype(np.int32)
               for l in lens]
    return lambda: [Request(prompt=p, max_new_tokens=max_new,
                            sampling=SamplingSpec(temperature=0.8, top_k=20,
                                                  seed=i))
                    for i, p in enumerate(prompts)]


def test_ragged_prefill_engine_matches_one_shot():
    """Ragged multi-prompt prefill (chunks of several prompts in ONE
    batched forward) must keep the chunked == one-shot bit-identity
    contract — and must actually take the ragged path."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    reqs = _mixed_requests(cfg)

    def run(**kw):
        eng = Engine(cfg, params, max_len=64, capacity=4, **kw)
        for r in reqs():
            eng.submit(r)
        return eng, [tuple(r.tokens) for r in eng.drain()]

    _, one = run(prefill_chunk=None)
    eng_r, ragged = run(prefill_chunk=2, ragged_prefill=True)
    _, static = run(prefill_chunk=2, ragged_prefill=False)
    assert eng_r._ragged and len(eng_r._ragged_fns) >= 1  # path exercised
    assert ragged == one
    assert static == one


def test_dispatch_depth_pipelining_bit_identical():
    """dispatch_depth=2 keeps a decode step in flight; token streams must
    be bit-identical to the synchronous depth-1 engine, including under
    staggered admission (pipeline drains before membership changes)."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    reqs = _mixed_requests(cfg)

    def run(depth, stagger):
        eng = Engine(cfg, params, max_len=64, capacity=4, prefill_chunk=2,
                     dispatch_depth=depth)
        rs = reqs()
        if stagger:
            eng.submit(rs[0]); eng.step(); eng.step()
            eng.submit(rs[1]); eng.submit(rs[2]); eng.step()
            eng.submit(rs[3])
        else:
            for r in rs:
                eng.submit(r)
        out = {r.request_id: tuple(r.tokens) for r in eng.drain()}
        assert not eng._inflight
        return [out[i] for i in range(4)]

    base = run(1, stagger=False)
    assert run(2, stagger=False) == base
    assert run(2, stagger=True) == base


# --------------------------------------------------------------------------
# Engine.abort: cancellation invariants (pages, CoW, reservations)
# --------------------------------------------------------------------------

def _pool_empty(pool):
    return (pool.pages_in_use == 0 and pool.pages_reserved == 0
            and sum(len(f) for f in pool._free) == pool.num_pages - 1)


def test_abort_queued_and_unknown_id():
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    eng = Engine(cfg, params, max_len=64, capacity=1, prefill_chunk=2)
    rng = np.random.default_rng(7)
    rid = eng.submit(Request(prompt=rng.integers(4, 128, size=12)
                             .astype(np.int32), max_new_tokens=4))
    res = eng.abort(rid)
    assert res.finish_reason == "aborted" and res.tokens == []
    assert eng.abort(rid) is None          # already gone
    assert eng.abort(12345) is None        # never submitted
    assert not eng._queue and eng.drain() == []


def test_abort_mid_prefill_and_mid_decode_releases_everything():
    """Aborting mid-prefill (no token yet) and mid-decode frees pages AND
    the unspent reservation; survivors' streams stay solo-identical and
    the drained pool is byte-for-byte empty."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    reqs = _mixed_requests(cfg)
    solo = {}
    for i, r in enumerate(reqs()):
        e = Engine(cfg, params, max_len=64, capacity=4, prefill_chunk=2)
        e.submit(r)
        solo[i] = tuple(e.drain()[0].tokens)

    eng = Engine(cfg, params, max_len=64, capacity=4, prefill_chunk=2,
                 dispatch_depth=2)
    rs = reqs()
    for r in rs:
        eng.submit(r)
    eng.step()                              # prompts mid-prefill
    r1 = eng.abort(rs[1].request_id)        # longest prompt: still prefilling
    assert r1.finish_reason == "aborted" and r1.ttft_s == 0.0
    for _ in range(4):
        eng.step()
    r2 = eng.abort(rs[2].request_id)        # decoding by now
    assert r2.finish_reason == "aborted" and len(r2.tokens) >= 1
    assert tuple(r2.tokens) == solo[2][:len(r2.tokens)]
    rest = {r.request_id: r for r in eng.drain()}
    assert set(rest) == {0, 3}
    for i in rest:
        assert tuple(rest[i].tokens) == solo[i]
    assert _pool_empty(eng.pool)


def test_abort_cow_prefix_sharer_keeps_page_alive():
    """Aborting one sharer of a CoW global-prefix page must decref — not
    free — the page: the surviving sharer keeps reading it and its stream
    stays solo-identical."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(4)
    prefix = rng.integers(4, cfg.vocab_size, size=8).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(4, cfg.vocab_size, size=n)
                               .astype(np.int32)]) for n in (20, 24)]
    e = Engine(cfg, params, max_len=64, capacity=2, prefill_chunk=2)
    e.submit(Request(prompt=prompts[1], max_new_tokens=12,
                     sampling=SamplingSpec(seed=1)))
    solo1 = tuple(e.drain()[0].tokens)

    eng = Engine(cfg, params, max_len=64, capacity=2, prefill_chunk=2)
    r0 = Request(prompt=prompts[0], max_new_tokens=12,
                 sampling=SamplingSpec(seed=0))
    r1 = Request(prompt=prompts[1], max_new_tokens=12,
                 sampling=SamplingSpec(seed=1))
    eng.submit(r0)
    while not eng.pool.decode_slots():      # prefix fully indexed
        eng.step()
    eng.submit(r1)
    eng.step()
    s1 = eng.pool.slots[1]
    assert s1 is not None and s1.shared_pages == 1
    shared_pg = s1.pages[0]
    assert eng.pool.refcount[shared_pg] == 2
    res0 = eng.abort(r0.request_id)         # abort the page's first owner
    assert res0.finish_reason == "aborted"
    assert eng.pool.refcount[shared_pg] == 1
    out = eng.drain()
    assert len(out) == 1 and tuple(out[0].tokens) == solo1
    assert _pool_empty(eng.pool)


def test_abort_unblocks_page_exhausted_queue():
    """A queued request waiting on pages must admit as soon as an abort
    returns them (reservation re-credit, not just mapped-page release)."""
    cfg = _small_cfg()
    params = M.init(cfg, KEY)
    rng = np.random.default_rng(6)
    # each request reserves ceil((24+8-1)/8) = 4 pages; pool holds 5 usable
    eng = Engine(cfg, params, max_len=64, capacity=3, num_pages=6,
                 prefill_chunk=2)
    rids = [eng.submit(Request(
        prompt=rng.integers(4, cfg.vocab_size, size=24).astype(np.int32),
        max_new_tokens=8, sampling=SamplingSpec(seed=i))) for i in range(2)]
    eng.step()
    assert eng.pool.slots[0] is not None and eng._queue  # req1 starved
    assert eng.abort(rids[0]).finish_reason == "aborted"
    eng.step()
    assert not eng._queue                   # admitted right after the abort
    out = eng.drain()
    assert len(out) == 1 and len(out[0].tokens) == 8
    assert _pool_empty(eng.pool)
