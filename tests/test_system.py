"""End-to-end behaviour tests: training convergence (CLM + the paper's MLM
objective), fault-tolerant restart, serving roundtrip, gradient compression,
multi-device sharding smoke (fake 8-device mesh in a subprocess)."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M

# system tier: multi-step training runs + subprocess mesh tests — excluded
# from the CI fast tier (-m "not slow"), run in the main-branch full tier
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code, env_extra=None):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_clm_training_learns():
    """Loss on the structured synthetic corpus must drop markedly."""
    cfg = configs.smoke("bigbird-base")
    opt = S.make_optimizer(schedule="constant", peak_lr=2e-3)
    ts = jax.jit(S.make_train_step(cfg, opt, microbatches=1),
                 donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                  batch_size=8, seed=1))
    params = M.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    first = last = None
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = ts(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.8, f"{first:.3f} -> {last:.3f}"


def test_mlm_training_learns():
    """The paper's objective: masked-token CE drops on held-out masking."""
    cfg = configs.smoke("bigbird-base")
    opt = S.make_optimizer(schedule="constant", peak_lr=2e-3)
    ts = jax.jit(S.make_train_step(cfg, opt, microbatches=1),
                 donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                  batch_size=8, seed=2, mlm=True))
    params = M.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    first = last = None
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = ts(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, f"{first:.3f} -> {last:.3f}"


def test_fault_tolerant_restart_resumes_step():
    """Kill training mid-run (simulated node failure), restart, and verify
    it resumes from the checkpoint and reaches the target step."""
    from repro.launch import train as T
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError, match="simulated node failure"):
            T.main(["--arch", "yi-6b", "--smoke", "--steps", "12",
                    "--batch", "2", "--seq", "64", "--ckpt-dir", d,
                    "--ckpt-every", "4", "--fail-at", "9",
                    "--log-every", "100"])
        from repro.ckpt import checkpoint as CKPT
        assert CKPT.latest_step(d) == 8          # survived checkpoints
        state = T.main(["--arch", "yi-6b", "--smoke", "--steps", "12",
                        "--batch", "2", "--seq", "64", "--ckpt-dir", d,
                        "--ckpt-every", "4", "--log-every", "100"])
        assert int(state["step"]) == 12


def test_serve_generates():
    from repro.launch import serve as SV
    toks = SV.main(["--arch", "h2o-danube-1.8b", "--smoke", "--batch", "2",
                    "--prompt-len", "48", "--gen", "8"])
    assert toks.shape == (2, 8)
    assert int(toks.min()) >= 0


def test_multi_device_sharded_train_step():
    """8 fake CPU devices: jit the real train step with the full sharding
    plumbing on a (4, 2) mesh and verify loss finiteness + resharded state."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro import configs
from repro.launch import steps as S
from repro.models import model as M
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = configs.smoke("yi-6b")
opt = S.make_optimizer()
ts = S.make_train_step(cfg, opt, microbatches=2)
st_ps = S.state_pspec_tree(cfg, opt, mesh)
from repro.launch.steps import _ns, _with_mesh
import numpy as np
params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, _ns(mesh, st_ps))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 128), 4, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
b_ps = _ns(mesh, S.batch_pspecs({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh))
batch = jax.tree.map(lambda x, s: jax.device_put(x, s), batch, b_ps)
jts = jax.jit(_with_mesh(ts, mesh), in_shardings=(_ns(mesh, st_ps), b_ps), donate_argnums=(0,))
state, m = jts(state, batch)
assert np.isfinite(float(m["loss"]))
state, m2 = jts(state, batch)
assert float(m2["loss"]) < float(m["loss"]) + 1.0
print("SHARDED_OK", float(m["loss"]))
"""
    out = _run(code)
    assert "SHARDED_OK" in out


def test_gradient_compression_error_feedback():
    """int8+EF compressed sync across a 2-pod mesh: biased once, unbiased
    over time (error feedback), and close to the exact mean."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim import compression as C
mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
# grads replicated in-pod, different across pods: emulate with pod-sharded input
g_pods = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
gspec = P()  # per-pod logical grads are replicated shards
def mean_exact():
    return g_pods.mean(0)
# shard_map over pod: each pod sees its own row
from functools import partial
def run(g_pods, e):
    def inner(gp, ep):
        out, err = C._sync_one(gp[0], ep[0], "pod")
        return out[None], err[None]
    try:
        shard_map = jax.shard_map
    except AttributeError:               # jax < 0.5: experimental namespace
        from jax.experimental.shard_map import shard_map
    fn = shard_map(inner, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=(P("pod"), P("pod")))
    return fn(g_pods, e)
e = jnp.zeros_like(g_pods)
out, e = run(g_pods, e)
exact = mean_exact()
err1 = float(jnp.abs(out[0] - exact).max())
assert err1 < 0.05, err1            # int8 quantization error is small
# error feedback: accumulated mean over repeated syncs converges
acc = jnp.zeros(64)
e = jnp.zeros_like(g_pods)
for _ in range(50):
    out, e = run(g_pods, e)
    acc = acc + out[0]
drift = float(jnp.abs(acc / 50 - exact).max())
assert drift < 0.01, drift          # EF removes the bias
print("COMPRESS_OK", err1, drift)
"""
    out = _run(code)
    assert "COMPRESS_OK" in out


def test_elastic_reshard_roundtrip():
    """Save on a (4,2) mesh, restore + reshard onto (2,2) (failure shrink)."""
    code = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch import steps as S
from repro.launch.steps import _ns
from repro.models import model as M
from repro.ckpt import checkpoint as CKPT
from repro.ft.elastic import plan_mesh, reshard_state
cfg = configs.smoke("yi-6b")
opt = S.make_optimizer()
mesh = jax.make_mesh((4, 2), ("data", "model"))
params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, _ns(mesh, S.state_pspec_tree(cfg, opt, mesh)))
with tempfile.TemporaryDirectory() as d:
    CKPT.save(state, d, step=5)
    restored, step = CKPT.restore(d)
    # "failure": only 4 devices remain -> (2,2) mesh
    new_mesh = plan_mesh(4, model_parallel=2).build()
    state2 = reshard_state(restored, cfg, opt, new_mesh)
    w0 = np.asarray(jax.tree.leaves(state["params"])[0])
    w1 = np.asarray(jax.tree.leaves(state2["params"])[0])
    np.testing.assert_array_equal(w0, w1)
    print("RESHARD_OK", step)
"""
    out = _run(code)
    assert "RESHARD_OK" in out
