"""Paper Table 1 — building-block ablation (R / W / R+W / BigBird).

Task with a genuinely long-range dependency (local context provably
uninformative): each row holds a KEY token right after the document head;
sparse RECALL markers appear >= 96 tokens apart (beyond the 5-block window
reach of +-40); the token after each RECALL must be the KEY.  The rest of
the row is a learnable local bigram stream.  Loss is evaluated on the
recall answers:

  * window(W)      — cannot reach the head: ~chance on recalls,
  * random(R)      — reaches block 0 with probability ~r/nb per layer,
  * R+W            — same reach, better local-stream handling,
  * bigbird(R+W+G) — the global block contains the key: 1-hop, solves it.

This reproduces the paper's Table-1 *mechanism* (the ablation ordering and
the necessity of global tokens) as a controlled experiment rather than its
absolute BERT-scale numbers.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core.attention import AttentionSpec, attention
from repro.launch import steps as S
from repro.models import model as M

STEPS = 700
SEQ = 256
BLOCK = 16
V = 512
HEAD, RECALL, MASK = 4, 5, 3
KEY_LO = 8


def _spec(w, g, r):
    return AttentionSpec(kind="bigbird", causal=False, block_size=BLOCK,
                         num_window_blocks=w, num_global_blocks=g,
                         num_random_blocks=r, impl="blockified")


VARIANTS = {
    "window(W)": _spec(5, 0, 0),
    "random(R)": _spec(1, 0, 3),
    "R+W": _spec(3, 0, 2),
    "bigbird(R+W+G)": _spec(3, 1, 2),
}


def recall_batch(step, B=8):
    rng = np.random.default_rng(step)
    toks = np.empty((B, SEQ), dtype=np.int64)
    # local bigram stream (fixed successor fn + 15% noise)
    prev = rng.integers(KEY_LO, V, size=B)
    for i in range(SEQ):
        det = rng.random(B) < 0.85
        toks[:, i] = np.where(det, (prev * 31 + 7) % (V - KEY_LO) + KEY_LO,
                              rng.integers(KEY_LO, V, size=B))
        prev = toks[:, i]
    keys = rng.integers(KEY_LO, V, size=B)
    toks[:, 0], toks[:, 1] = HEAD, keys
    # recall sites spaced >= 110 apart and >= 100 from the head
    labels = toks.copy()
    lm = np.zeros((B, SEQ), np.float32)
    for b in range(B):
        sites = 100 + np.arange(2) * 110 + rng.integers(0, 8)
        for p in sites:
            toks[b, p], toks[b, p + 1] = RECALL, keys[b]
            labels[b, p + 1] = keys[b]
            lm[b, p + 1] = 1.0
    inp = toks.copy()
    inp[lm.astype(bool)] = MASK                  # mask the recall answers
    # plus ordinary MLM masking on the stream (keeps the task honest)
    mlm = (rng.random((B, SEQ)) < 0.10) & (lm == 0)
    mlm[:, :2] = False
    inp[mlm] = MASK
    lm = lm + mlm.astype(np.float32)
    return {"tokens": inp.astype(np.int32), "labels": labels.astype(np.int32),
            "loss_mask": lm}


def recall_only_loss(params, cfg, step):
    """Held-out CE evaluated ONLY on the recall-answer positions."""
    rb = recall_batch(step)
    mask = np.zeros_like(rb["loss_mask"])
    for bb in range(rb["tokens"].shape[0]):
        for p in range(1, SEQ):
            if rb["tokens"][bb, p - 1] == RECALL:
                mask[bb, p] = 1.0
    batch = {k: jnp.asarray(v) for k, v in rb.items()}
    batch["loss_mask"] = jnp.asarray(mask)
    return float(M.loss_fn(params, cfg, batch))


def _variant_cfg(spec):
    return M.ModelConfig(
        name="tab1", d_model=48, num_layers=2, num_heads=4, num_kv_heads=4,
        head_dim=12, d_ff=96, vocab_size=V, attn=spec,
        dtype=jnp.float32, scan_layers=False, remat="none", loss_chunk=64)


def train_variant(spec):
    cfg = _variant_cfg(spec)
    opt = S.make_optimizer(schedule="constant", peak_lr=5e-3)
    ts = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
    params = M.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    for step in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in recall_batch(step).items()}
        state, m = ts(state, batch)
    ev = sum(recall_only_loss(state["params"], cfg, s)
             for s in range(5000, 5004)) / 4
    # held-out NLL over ALL masked positions (recall answers + MLM stream):
    # the quality axis of the policy sweep — policies share the global
    # block (so recall alone cannot separate them) but differ in how the
    # non-global budget is spent on the local stream
    nll = 0.0
    for s in range(6000, 6004):
        batch = {k: jnp.asarray(v) for k, v in recall_batch(s).items()}
        nll += float(M.loss_fn(state["params"], cfg, batch))
    return ev, nll / 4


REACH_SEQ = 1024


def head_reach(spec, hops=3):
    """EXACT long-range reachability: fraction of far positions (second half
    of a 1024-token row) whose hidden state can absorb the document head
    (position 1) within `hops` attention layers — the information-flow
    quantity behind Table 1, computed from the adjacency matrix
    (training-free, deterministic).  Random attention mixes like an expander
    (fast growth per hop); window diffuses linearly; global tokens give
    diameter <= 2 (the star graph of Theorem 1)."""
    from repro.core import patterns
    cfg = spec.bigbird_config(REACH_SEQ)
    pat = patterns.build_pattern(cfg, REACH_SEQ)
    A = patterns.dense_mask(pat)
    R = A.copy()
    for _ in range(hops - 1):
        R = (R.astype(np.int64) @ A > 0) | R
    far = np.arange(REACH_SEQ // 2, REACH_SEQ)
    return float(R[far, 1].mean())


FB_SEQ = 1024


def fwd_bwd_bench():
    """Trainability column: fwd and fwd+bwd wall-clock, blockified vs fused.

    The fused path runs its custom_vjp backward Pallas kernels (dQ + dK/dV);
    on CPU they execute in interpret mode, so the CPU numbers measure
    correctness-path overhead — the TPU win comes from never materializing
    the packed K''/V'' tensors (fwd) nor their gradients (bwd).
    """
    B, H, d = 1, 4, 32
    spec = AttentionSpec(kind="bigbird", causal=True, block_size=64,
                         num_window_blocks=3, num_global_blocks=2,
                         num_random_blocks=3)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    cot = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    times = {}
    for impl in ("blockified", "pallas"):
        sp = dataclasses.replace(spec, impl=impl)
        fwd = jax.jit(lambda q, k, v, sp=sp: attention(q, k, v, sp))
        fb = jax.jit(jax.value_and_grad(
            lambda q, k, v, sp=sp: jnp.sum(attention(q, k, v, sp) * cot),
            argnums=(0, 1, 2)))
        us_f, _ = time_call(fwd, q, k, v)
        us_fb, (_, grads) = time_call(fb, q, k, v)
        assert all(bool(jnp.isfinite(g).all()) for g in grads)
        times[impl] = (us_f, us_fb)
        label = "fused" if impl == "pallas" else impl
        row(f"tab1_fwd_{label}", us_f, f"S={FB_SEQ};bwd=no")
        row(f"tab1_fwdbwd_{label}", us_fb, f"S={FB_SEQ};bwd=custom_vjp"
            if impl == "pallas" else f"S={FB_SEQ};bwd=xla_autodiff")
    row("tab1_fwdbwd_blockified_vs_fused", 0.0,
        f"S={FB_SEQ};blockified_us={times['blockified'][1]:.0f};"
        f"fused_us={times['pallas'][1]:.0f};"
        f"ratio={times['blockified'][1] / max(times['pallas'][1], 1e-9):.3f}")
    return times


POLICIES = ("bigbird", "importance", "littlebird")


def policy_fwd_bwd(pol):
    """Per-policy fwd and fwd+bwd wall-clock through the fused Pallas path.

    Paper-sized blocks (64), causal, matched slot budget across policies —
    the layouts differ only in where the non-global slots point, so fwd
    cost is matched by construction; the backward differs through the
    transposed map's padded width U (littlebird's regular window keeps the
    in-degree exactly w+r, while random/importance picks concentrate on
    low-index blocks and pad U up to ~w + r·log nb).
    Returns (fwd_us, fwdbwd_us, U)."""
    from repro.core import patterns
    B, H, d = 1, 4, 32
    spec = AttentionSpec(kind="bigbird", causal=True, block_size=64,
                         num_window_blocks=3, num_global_blocks=2,
                         num_random_blocks=3, impl="pallas", pattern=pol)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    cot = jnp.asarray(rng.standard_normal((B, H, FB_SEQ, d)), jnp.float32)
    fwd = jax.jit(lambda q, k, v: attention(q, k, v, spec))
    fb = jax.jit(jax.value_and_grad(
        lambda q, k, v: jnp.sum(attention(q, k, v, spec) * cot),
        argnums=(0, 1, 2)))
    us_f, _ = time_call(fwd, q, k, v)
    us_fb, (_, grads) = time_call(fb, q, k, v)
    assert all(bool(jnp.isfinite(g).all()) for g in grads)
    tq, _ = patterns.transposed_pattern(spec.bigbird_config(FB_SEQ), FB_SEQ)
    return us_f, us_fb, tq.shape[1]


def policy_sweep():
    """NLL-vs-speed sweep over the registered pattern policies.

    One row per policy: held-out masked NLL (+ recall-only NLL) after the
    700-step MLM run on the recall corpus, per-step train wall-clock,
    fused fwd/fwd+bwd kernel timings at S=1024, and a decode-throughput
    row measured through the serving engine (benchmarks/serving.py's
    decode_throughput — same engine, paged pool and kernels; only the
    block layout changes).  A final verdict row per non-default policy
    says whether it beats the default at matched quality or matched speed
    — the evidence for promoting a policy to a registered config.
    """
    from benchmarks.serving import decode_throughput
    out = {}
    for pol in POLICIES:
        spec = dataclasses.replace(_spec(3, 1, 2), pattern=pol)
        t0 = time.perf_counter()
        recall, nll = train_variant(spec)
        train_us = (time.perf_counter() - t0) * 1e6 / STEPS
        fwd_us, fb_us, U = policy_fwd_bwd(pol)
        dcfg = dataclasses.replace(
            _variant_cfg(dataclasses.replace(
                spec, causal=True, num_random_blocks=2)),
            name=f"sweep-{pol}")
        params = M.init(dcfg, jax.random.PRNGKey(0))
        ttft, dec = decode_throughput(dcfg, params, batch=4, prompt_len=128,
                                      gen=16, max_len=256)
        out[pol] = {"nll": nll, "recall": recall, "train_us": train_us,
                    "fwd_us": fwd_us, "fb_us": fb_us, "dec": dec}
        row(f"policy_{pol}", fb_us,
            f"mlm_nll={nll:.4f};recall_nll={recall:.4f};"
            f"train_us_step={train_us:.0f};fwd_us={fwd_us:.0f};"
            f"fwdbwd_us={fb_us:.0f};bwd_U={U};decode_tok_s={dec:.1f};"
            f"ttft_s={ttft:.3f}")
    base = out["bigbird"]
    for pol in POLICIES[1:]:
        o = out[pol]
        # wins = better NLL at matched (<= +2%) wall-clock, or matched
        # (<= +2%) NLL at better wall-clock, on the fwd+bwd timing
        win = ((o["nll"] < base["nll"] and o["fb_us"] <= base["fb_us"] * 1.02)
               or (o["nll"] <= base["nll"] * 1.02
                   and o["fb_us"] < base["fb_us"]))
        row(f"policy_sweep_{pol}_vs_default", 0.0,
            f"mlm_nll={o['nll']:.4f}_vs_{base['nll']:.4f};"
            f"fwdbwd_us={o['fb_us']:.0f}_vs_{base['fb_us']:.0f};"
            f"decode_tok_s={o['dec']:.1f}_vs_{base['dec']:.1f};wins={win}")
    return out


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", action="store_true",
                    help="run only the pattern-policy NLL-vs-speed sweep "
                         "(default: the full Table-1 bench + the sweep)")
    args = ap.parse_args(argv)
    if args.policies:
        return policy_sweep()
    results = {}
    # trainability: fwd+bwd wall-clock comparison (blockified vs fused kernel)
    fwd_bwd_bench()
    # exact mechanism: k-hop reach to the head, per pattern
    for name, spec in VARIANTS.items():
        r2, r3 = head_reach(spec, 2), head_reach(spec, 3)
        results[f"reach_{name}"] = r3
        row(f"tab1_reach_{name}", 0.0,
            f"head_reach_2hop={r2:.3f};3hop={r3:.3f}")
    w, r = results["reach_window(W)"], results["reach_random(R)"]
    rw, bb = results["reach_R+W"], results["reach_bigbird(R+W+G)"]
    row("tab1_reach_ordering", 0.0,
        f"W({w:.2f})<R({r:.2f})<R+W({rw:.2f})<bigbird({bb:.2f}):"
        f"ordering_ok={w < r < rw < bb and bb == 1.0}")
    # trained MLM on the recall corpus (700 CPU steps — reported for
    # completeness; content-routing needs more steps than the CPU budget,
    # so the exact reach metric above carries the Table-1 ordering claim)
    for name, spec in VARIANTS.items():
        t0 = time.perf_counter()
        loss, _ = train_variant(spec)
        us = (time.perf_counter() - t0) * 1e6 / STEPS
        results[name] = loss
        row(f"tab1_{name}", us, f"recall_loss={loss:.4f}")
    # NLL-vs-speed sweep over pattern policies (core/patterns.py)
    results["policies"] = policy_sweep()
    return results


if __name__ == "__main__":
    main()
