"""Paper App. D — the blockification trick.

Compares three implementations of the SAME sparse attention graph:
  * gather      — per-query-block jnp.take of its key blocks (GPU-naive),
  * blockified  — rolled key tensor + static slices (the paper's impl),
  * dense       — full attention + mask (the O(n^2) strawman).

Derived: speedup of blockified over gather and over dense at seq 2048 —
the paper's justification for the whole App-D design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import patterns
from repro.core.blockified import bigbird_attention_blockified
from repro.core.ref_attention import bigbird_attention_reference

CFG = patterns.BigBirdConfig(block_size=64, num_window_blocks=3,
                             num_global_blocks=1, num_random_blocks=2)


def gather_impl(q, k, v, cfg=CFG):
    """Naive: one gather per query block over ALL slot indices."""
    B, H, S, d = q.shape
    pat = patterns.build_pattern(cfg, S)
    nb, L = pat.num_blocks, pat.slots
    b = cfg.block_size
    idx = jnp.asarray(pat.key_blocks)                     # (nb, L)
    kb = k.reshape(B, H, nb, b, d)
    vb = v.reshape(B, H, nb, b, d)
    kk = jnp.take(kb, idx.reshape(-1), axis=2).reshape(B, H, nb, L * b, d)
    vv = jnp.take(vb, idx.reshape(-1), axis=2).reshape(B, H, nb, L * b, d)
    qb = q.reshape(B, H, nb, b, d)
    sc = jnp.einsum("bhnqd,bhnkd->bhnqk", qb, kk) / np.sqrt(d)
    mask = jnp.asarray(pat.token_level_slot_mask())[None, None, :, None, :]
    sc = jnp.where(mask, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhnqk,bhnkd->bhnqd", p, vv)
    return out.reshape(B, H, S, d)


def main():
    B, H, S, d = 1, 4, 2048, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, d))
    k = jax.random.normal(key, (B, H, S, d))
    v = jax.random.normal(key, (B, H, S, d))

    f_gather = jax.jit(gather_impl)
    f_block = jax.jit(lambda q, k, v: bigbird_attention_blockified(q, k, v, CFG))
    f_dense = jax.jit(lambda q, k, v: bigbird_attention_reference(q, k, v, CFG))

    us_g, out_g = time_call(f_gather, q, k, v)
    us_b, out_b = time_call(f_block, q, k, v)
    us_d, out_d = time_call(f_dense, q, k, v)
    row("blockify_gather", us_g, f"S={S}")
    row("blockify_rolled", us_b, f"S={S}")
    row("blockify_dense_masked", us_d, f"S={S}")
    row("blockify_speedup", 0.0,
        f"vs_gather={us_g/us_b:.2f}x,vs_dense={us_d/us_b:.2f}x")

    # the STRUCTURAL claim (App. D): blockification removes gathers from the
    # window/global components — only the tiny random part gathers.  Count
    # gathered BYTES in the lowered module (backend-independent; CPU
    # wall-times under-sell it because CPU gathers are cheap, TPU's are not).
    import re

    def gather_bytes(fn):
        txt = jax.jit(fn).lower(q, k, v).as_text()
        total = 0
        for m in re.finditer(
                r'"stablehlo\.gather".*?->\s*tensor<([0-9x]+)xf32>', txt):
            n = 1
            for dim in m.group(1).split("x"):
                if dim:
                    n *= int(dim)
            total += 4 * n
        return total

    bg = gather_bytes(gather_impl)
    bb_ = gather_bytes(lambda q, k, v: bigbird_attention_blockified(q, k, v, CFG))
    L = (CFG.num_global_blocks + CFG.num_window_blocks + CFG.num_random_blocks)
    row("blockify_gather_bytes", 0.0,
        f"gather_impl={bg},blockified={bb_},reduction="
        f"{bg / max(bb_, 1):.1f}x,expected~{L / CFG.num_random_blocks:.0f}x")
    # all three must agree (excluding global rows handled only by blockified)
    g = CFG.num_global_blocks * CFG.block_size
    err = float(jnp.max(jnp.abs(out_b[:, :, g:] - out_d[:, :, g:])))
    row("blockify_agreement", 0.0, f"max_err={err:.2e}")
    return us_g, us_b, us_d


if __name__ == "__main__":
    main()
