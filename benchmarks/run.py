"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.row).

  building_blocks — Table 1 (R / W / R+W / BigBird MLM ablation)
  scaling         — Sec. 1-2 linear-complexity + 8x-longer-sequence claims
  blockify        — App. D blockified-vs-gather-vs-dense implementation
  encdec_parity   — Sec. 4.1 sparse-encoder seq2seq parity (Tab. 4/20)
  context_length  — Fig. 8 / Tab. 5: longer context helps MLM
  roofline_table  — §Roofline rows from the dry-run artifacts
  serving         — Engine TTFT + decode tok/s (+ SERVING_JSON line)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ["scaling", "blockify", "building_blocks", "encdec_parity",
           "context_length", "roofline_table", "serving"]


def _run_one(name: str) -> bool:
    """Import + run one benchmark; True on success.

    Failure handling is deliberately broad: a sub-benchmark that raises,
    or that aborts itself via SystemExit (argparse errors included), must
    turn into a nonzero harness exit — a silently-green failing bench
    would defeat the CI perf gate that diffs this run's SERVING_JSON.
    The harness's own argv is hidden from sub-benchmark argparsers."""
    argv = sys.argv
    sys.argv = [name]
    try:
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        mod.main()
        return True
    except SystemExit as e:           # sub-bench bailed out on its own —
        traceback.print_exc()         # even exit(0) means it never produced
        print(f"{name},0.0,ERROR:SystemExit({e.code})")   # its rows
        return False
    except Exception as e:            # report and continue with the rest
        traceback.print_exc()
        print(f"{name},0.0,ERROR:{type(e).__name__}")
        return False
    finally:
        sys.argv = argv


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, metavar="NAME",
                    help="run only the named sub-benchmark(s), e.g. "
                         "--only serving (choices: " + ", ".join(BENCHES)
                         + ")")
    args = ap.parse_args()
    unknown = [n for n in (args.only or []) if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choices: {BENCHES}")
    names = args.only or BENCHES
    failures = []
    for name in names:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        if not _run_one(name):
            failures.append(name)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == '__main__':
    main()
