"""Paper headline claim (Sec. 1/2): BigBird attention is LINEAR in sequence
length, enabling ~8x longer sequences on the same memory than full attention.

Two measurements:
  * wall-time per attention call (blockified impl) across 512..8192 — the
    growth exponent should be ~1 (vs ~2 for full attention);
  * activation memory of the attention operator (analytic bytes, the same
    accounting the dry-run uses) — solve for the max sequence at BERT's
    512-full-attention budget: expect >= 8x.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import row, time_call
from repro.core import patterns
from repro.core.blockified import bigbird_attention_blockified
from repro.core.ref_attention import full_attention_reference

CFG = patterns.BigBirdConfig(block_size=64, num_window_blocks=3,
                             num_global_blocks=2, num_random_blocks=3)


def attn_bytes_full(S, H=12, dh=64, dtype_bytes=4):
    return H * S * S * dtype_bytes          # score matrix per head


def attn_bytes_bigbird(S, H=12, dh=64, dtype_bytes=4):
    b = CFG.block_size
    L = CFG.num_global_blocks + CFG.num_window_blocks + CFG.num_random_blocks
    return H * S * L * b * dtype_bytes      # packed scores per head


def main():
    H, dh = 4, 32
    times_bb, times_full, seqs = [], [], [512, 1024, 2048, 4096]
    fn_bb = jax.jit(lambda q, k, v: bigbird_attention_blockified(q, k, v, CFG))
    fn_full = jax.jit(lambda q, k, v: full_attention_reference(q, k, v))
    for S in seqs:
        key = jax.random.PRNGKey(S)
        q = jax.random.normal(key, (1, H, S, dh))
        k = jax.random.normal(key, (1, H, S, dh))
        v = jax.random.normal(key, (1, H, S, dh))
        us, _ = time_call(fn_bb, q, k, v)
        times_bb.append(us)
        row(f"scaling_bigbird_S{S}", us, f"us_per_token={us/S:.2f}")
        if S <= 2048:                        # full blows up beyond this
            usf, _ = time_call(fn_full, q, k, v)
            times_full.append(usf)
            row(f"scaling_full_S{S}", usf, f"us_per_token={usf/S:.2f}")
    # growth exponents via log-log fit
    e_bb = np.polyfit(np.log(seqs), np.log(times_bb), 1)[0]
    e_full = np.polyfit(np.log(seqs[:len(times_full)]),
                        np.log(times_full), 1)[0]
    row("scaling_exponent_bigbird", 0.0, f"exponent={e_bb:.2f}")
    row("scaling_exponent_full", 0.0, f"exponent={e_full:.2f}")

    # 8x-longer-sequences claim, formalized at iso-cost-per-token:
    # BigBird attends (g+w+r)*b = 512 keys/query at ANY length — exactly the
    # per-token cost of full attention at 512.  Full attention at the
    # paper's 4096 costs 8x more per token; BigBird holds it constant.
    b = CFG.block_size
    keys_per_query = (CFG.num_global_blocks + CFG.num_window_blocks
                      + CFG.num_random_blocks) * b
    ratio = 4096 / keys_per_query
    row("iso_cost_max_seq", 0.0,
        f"keys_per_query={keys_per_query},full_cost_at_4096={ratio:.0f}x,"
        f"claim_8x={ratio >= 8}")
    # and the memory ratio of the attention operator at 4096:
    mem_ratio = attn_bytes_full(4096) / attn_bytes_bigbird(4096)
    row("attn_memory_ratio_at_4096", 0.0, f"full_vs_bigbird={mem_ratio:.1f}x")
    return e_bb, e_full


if __name__ == "__main__":
    main()
