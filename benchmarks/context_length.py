"""Paper Fig. 8 / Table 5 — longer context improves MLM.

Trains the same tiny BigBird MLM at increasing context lengths on the same
corpus; derived: held-out MLM loss per context length (expect monotone
improvement — Fig. 8's "BIGBIRD accuracy with context length").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core.attention import AttentionSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M

STEPS = 80


def train_ctx(seq_len):
    spec = AttentionSpec(kind="bigbird", causal=False, block_size=16,
                         num_window_blocks=3, num_global_blocks=1,
                         num_random_blocks=2, impl="blockified")
    cfg = M.ModelConfig(name=f"ctx{seq_len}", d_model=64, num_layers=2,
                        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                        vocab_size=512, attn=spec, dtype=jnp.float32,
                        scan_layers=False, remat="none", loss_chunk=64)
    opt = S.make_optimizer(schedule="constant", peak_lr=2e-3)
    ts = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
    # tokens-per-batch held constant so every run sees equal data.
    # Topic-headed packed docs (doc length 300-600): short contexts mostly
    # start mid-document with the head out of reach; long contexts contain
    # the heads — the Fig-8 mechanism.
    bsz = max(2048 // seq_len, 1)
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=seq_len,
                                  batch_size=bsz, seed=17, mlm=True,
                                  num_topics=8, doc_len_range=(300, 600)))
    params = M.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    for step in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, _ = ts(state, batch)
    ev = 0.0
    for step in range(800, 804):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        ev += float(M.loss_fn(state["params"], cfg, batch))
    return ev / 4


def resolvable_fraction(seq_len, samples=20):
    """EXACT information-availability carrier of Fig. 8: the fraction of
    token positions whose document head (the topic token, 4..11) is present
    earlier in the same row — the upper bound on topic-conditional MLM
    accuracy at this context length.  Deterministic in the data pipeline;
    grows with context because short rows mostly start mid-document."""
    import numpy as np
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=seq_len,
                                  batch_size=4, seed=17, mlm=True,
                                  num_topics=8, doc_len_range=(300, 600)))
    tot = got = 0
    for s in range(samples):
        toks = data.batch(10_000 + s)["labels"]
        for rowv in toks:
            heads = np.isin(rowv, np.arange(4, 12))
            seen = np.cumsum(heads) > 0
            got += int(seen.sum())
            tot += len(rowv)
    return got / tot


def main():
    losses = {}
    fracs = {}
    for seq in (128, 256, 512):
        fracs[seq] = resolvable_fraction(seq)
        row(f"ctxlen_resolvable_S{seq}", 0.0,
            f"head_in_context_frac={fracs[seq]:.3f}")
        losses[seq] = train_ctx(seq)
        row(f"ctxlen_mlm_S{seq}", 0.0, f"heldout_loss={losses[seq]:.4f}")
    mono = fracs[128] < fracs[256] < fracs[512]
    row("ctxlen_longer_resolves_more", 0.0,
        f"monotone={mono} (exact availability bound; trained losses at 80 "
        "CPU steps don't yet exploit it — see building_blocks note)")
    return losses


if __name__ == "__main__":
    main()
