"""Serving benchmark: TTFT, decode tok/s, and paged-KV memory accounting.

Measurements over a small BigBird LM (bounded decode, paged KV pool):
  serving_ttft          — warm prefill + first sampled token (generate(1));
  serving_decode        — steady-state jitted-loop decode tok/s;
  serving_continuous    — page-pool throughput with staggered admits,
                          ragged multi-prompt chunked prefill, heterogeneous
                          prompt lengths and a shared prompt prefix
                          (prefix-page hits);
  serving_poisson       — the same requests re-served under seeded OPEN-LOOP
                          Poisson arrivals (the clock, not the engine, owns
                          admission): TTFT/TPOT p50/p95 tail latency.  Token
                          streams are schedule-independent, so the digest
                          must equal the continuous section's;
  serving_stream        — the workload through the AsyncEngine front-end
                          (per-request asyncio token streams, dispatch_depth
                          2): streamed tokens must be digest-identical to
                          the synchronous drain (`stream_outputs_match`);
  serving_spec          — (--spec) the same continuous workload through the
                          speculative draft/verify path: the n-gram
                          provider, or (--spec-provider tree) a draft model
                          distilled IN-JOB from the bench target (fixed
                          seed and step budget) proposing token trees
                          verified in one paged forward.  Reports
                          spec-vs-vanilla tok/s, acceptance rate, the
                          accepted-length histogram and (tree) per-depth
                          off-spine stats.  Greedy speculation is
                          lossless, so `spec_outputs_match` asserts the
                          spec digest equals the vanilla digest — a CI-level
                          restatement of the token-identity contract.  The
                          bench target itself is briefly pretrained at
                          build time (seed 0, fixed steps) so acceptance
                          is measured against a model, not noise;
  serving_int8          — (--kv-dtype int8) the workload on quantized KV
                          pages: bytes/request and same-HBM concurrency
                          under int8, plus `int8_nll_delta` — the mean
                          teacher-forced NLL inflation of the f32 engine's
                          streams when scored through the int8 paged path
                          (Engine.score; int8 is lossy, so quality, not
                          digests, is the gated contract) and, with --spec,
                          `spec_acceptance_rate_int8`;
  serving_swap          — (--host-swap) the workload on a pool starved to
                          less than half its peak working set, with the
                          host-memory swap tier absorbing the pressure:
                          `swap_outputs_match` asserts the swapped run's
                          digest equals the unswapped continuous digest
                          (the swap tier is EXACT by construction), with
                          swap_in/out traffic and the host-page peak.

Memory rows compare the paged pool against the slot-contiguous layout it
replaced (capacity x max_len reservation per slot):
  kv_bytes_per_request_{paged,slot}, kv_reduction (1 - paged/slot),
  unused_tail_frac (the mean tail a contiguous slot wastes — the floor the
  reduction is judged against), max_concurrency_{paged,slot} under the same
  HBM budget.

`--mesh DxM` serves the continuous-batching section over a (data, model)
mesh (DESIGN.md §Mesh-parallel serving): slots/pages shard over data, kv
heads over model.  SERVING_JSON then carries per-shard KV bytes and the
aggregate tok/s, plus `outputs_digest` — a hash of every request's token
stream, which must be IDENTICAL across mesh shapes (the sharded
bit-identity contract; the CI multi-device job diffs 2x2 against 1x1).

Prints the standard `name,us_per_call,derived` CSV rows plus one JSON line
(`SERVING_JSON {...}`) for the bench trajectory and the CI perf gate
(benchmarks/perf_gate.py).
"""
from __future__ import annotations

import argparse
import asyncio
import hashlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.attention import AttentionSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as S
from repro.models import model as M
from repro.obs import metrics as Om
from repro.obs import trace as Otr
from repro.serve import AsyncEngine, Engine, Request, SamplingSpec, SpecConfig

B, PROMPT, GEN, MAXLEN = 4, 256, 24, 512
POISSON_GAP_S = 0.08               # mean interarrival (seeded open loop)
PRETRAIN_STEPS = 300               # fixed budget: the bench checkpoint is a
#                                    pure function of (seed 0, 300 steps)
DISTILL_STEPS = 300                # ditto for the in-job distilled draft
TRAIN_SEQ = 128                    # pretrain/distill sequence length


def _build():
    bigbird = AttentionSpec(kind="bigbird", causal=True, block_size=32,
                            num_window_blocks=3, num_global_blocks=1,
                            num_random_blocks=1, impl="blockified")
    cfg = M.ModelConfig(name="bench-serve", d_model=128, num_layers=4,
                        num_heads=4, num_kv_heads=2, d_ff=512,
                        vocab_size=1024, attn=bigbird, dtype=jnp.float32,
                        scan_layers=False, remat="none", loss_chunk=128)
    params = M.init(cfg, jax.random.PRNGKey(0))
    # brief deterministic pretraining on the structured synthetic corpus
    # (data/pipeline.py): the served model must be a trained LM, not noise.
    # A random-init target's argmax is an unlearnable function, so any
    # draft-acceptance measurement against it gates nothing; a fixed seed
    # and step budget keep the checkpoint (and every digest downstream of
    # it) reproducible across runs.
    opt = S.make_optimizer(kind="adamw", schedule="cosine", peak_lr=3e-3,
                           warmup=20, total=PRETRAIN_STEPS)
    train = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=TRAIN_SEQ, batch_size=8, seed=0,
                                  mlm=False))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    for i in range(PRETRAIN_STEPS):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = train(state, b)
    print(f"# bench target: {PRETRAIN_STEPS} pretrain steps, final "
          f"loss {float(metrics['loss']):.3f}")
    return cfg, state["params"]


def _distill_draft(tcfg, tparams):
    """In-job distillation (the launch/train.py --distill objective): a
    small draft trained with per-position KL against the bench target's
    logits.  Batches alternate between the synthetic corpus and uniform-
    random token streams: the bench prompts are random tokens, and the
    teacher's next-token map (largely the corpus' context-free bigram)
    applies there too — but the draft only matches it on contexts it was
    distilled on.  Fixed seeds + step budget, so the draft checkpoint —
    and the tree-spec acceptance rate measured with it — is
    reproducible."""
    dcfg = M.ModelConfig(name="bench-draft", d_model=64, num_layers=2,
                         num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=tcfg.vocab_size, attn=tcfg.attn,
                         dtype=jnp.float32, scan_layers=False, remat="none",
                         loss_chunk=128)
    opt = S.make_optimizer(kind="adamw", schedule="cosine", peak_lr=3e-3,
                           warmup=20, total=DISTILL_STEPS)
    dstep = jax.jit(S.make_distill_step(dcfg, tcfg, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=tcfg.vocab_size,
                                  seq_len=TRAIN_SEQ, batch_size=8, seed=1,
                                  mlm=False))
    rng = np.random.default_rng(1)
    params = M.init(dcfg, jax.random.PRNGKey(1))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    for i in range(DISTILL_STEPS):
        if i % 2 == 0:
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        else:
            t = rng.integers(4, tcfg.vocab_size,
                             size=(8, TRAIN_SEQ)).astype(np.int32)
            b = {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}
        state, metrics = dstep(state, tparams, b)
    agree = float(metrics["agree"])
    print(f"# distilled draft: {DISTILL_STEPS} KL steps, teacher argmax "
          f"agreement {agree:.3f}")
    return dcfg, state["params"], agree


def decode_throughput(cfg, params, *, batch=4, prompt_len=128, gen=16,
                      max_len=256):
    """Warm TTFT + steady-state decode tok/s for one engine config.

    The same measurement recipe as the ttft/decode section of main()
    (warm generate(1)/generate(gen), then time both), packaged so other
    benches — building_blocks.py's pattern-policy sweep — can report a
    decode-throughput row per config without duplicating the protocol.
    Returns (ttft_s, decode_tok_s)."""
    engine = Engine(cfg, params, max_len=max_len, capacity=batch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size,
                            size=prompt_len).astype(np.int32)
               for _ in range(batch)]
    engine.generate(prompts, max_new=1)
    engine.generate(prompts, max_new=gen)
    t0 = time.perf_counter()
    engine.generate(prompts, max_new=1)
    ttft = time.perf_counter() - t0
    t0 = time.perf_counter()
    engine.generate(prompts, max_new=gen)
    t_gen = time.perf_counter() - t0
    dec_tps = batch * (gen - 1) / max(t_gen - ttft, 1e-9)
    return ttft, dec_tps


def _digest(results) -> str:
    """Schedule-independent hash of every request's token stream.  Ids are
    normalized to submission order so runs of the same workload through
    different front-ends (drain / Poisson / async streaming) compare."""
    base = min(r.request_id for r in results)
    payload = json.dumps(sorted((r.request_id - base, r.tokens)
                                for r in results))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve the continuous section over a (data, model) "
                         "mesh, e.g. 2x2 (needs D*M visible devices)")
    ap.add_argument("--spec", action="store_true",
                    help="also run the continuous workload through the "
                         "speculative draft/verify path")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per verify round (default 4)")
    ap.add_argument("--spec-provider", default="ngram",
                    choices=("ngram", "tree"),
                    help="ngram: prompt-lookup statistical draft; tree: a "
                         "draft model distilled IN-JOB from the bench "
                         "target (fixed seed/steps) proposing a token tree "
                         "verified in one paged forward")
    ap.add_argument("--spec-fanout", default=None, metavar="F1,F2,..",
                    help="tree branching per depth (default 2 per depth "
                         "over K levels)")
    ap.add_argument("--kv-dtype", default=None, choices=(None, "int8"),
                    help="also run the workload on quantized KV pages and "
                         "report bytes/concurrency/NLL-delta")
    ap.add_argument("--host-swap", action="store_true",
                    help="also run the workload on a starved pool with the "
                         "host-memory swap tier (digest-gated)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record per-request timelines + engine phase spans "
                         "during the measured sections and write Chrome "
                         "trace-event JSON here (perfetto-loadable)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve live Prometheus metrics while the bench "
                         "runs (0 = ephemeral port) and self-scrape "
                         "/metrics at the end (metrics_endpoint_ok)")
    args = ap.parse_args(argv)
    assert not ((args.kv_dtype or args.host_swap)
                and args.mesh and args.mesh != "1x1"), \
        "the int8/swap sections run on the unsharded engine"
    mesh = None
    mesh_name = "1x1"
    if args.mesh and args.mesh != "1x1":
        from repro.serve import mesh as Mx
        mesh = Mx.parse_mesh(args.mesh)
        mesh_name = args.mesh

    mserver = None
    if args.metrics_port is not None:
        from repro.obs import server as Osrv
        mserver = Osrv.start_metrics_server(args.metrics_port)
        print(f"# metrics: http://127.0.0.1:{mserver.port}/metrics")

    cfg, params = _build()
    engine = Engine(cfg, params, max_len=MAXLEN, capacity=B, mesh=mesh)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size, size=PROMPT).astype(np.int32)
               for _ in range(B)]

    # warm every executable first (compile excluded from all timings)
    engine.generate(prompts, max_new=1)
    engine.generate(prompts, max_new=GEN)

    t0 = time.perf_counter()
    engine.generate(prompts, max_new=1)
    ttft = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine.generate(prompts, max_new=GEN)
    t_gen = time.perf_counter() - t0
    dec_steps = GEN - 1
    dec_toks = B * dec_steps
    dec_tps = dec_toks / max(t_gen - ttft, 1e-9)

    # continuous batching: 2x oversubscribed, staggered, ragged prompts.
    # Every request opens with the same "system prompt" covering the global
    # block, so co-residents hit the shared-prefix pages.
    g_prefix = rng.integers(4, cfg.vocab_size,
                            size=engine.pool.page_size).astype(np.int32)
    lens = rng.integers(PROMPT // 4, PROMPT, size=2 * B)
    # one fixed prompt set: every wave (warmup, vanilla, spec) serves the
    # same tokens, so greedy digests are comparable across sections
    wl_prompts = [np.concatenate(
        [g_prefix, rng.integers(4, cfg.vocab_size,
                                size=int(l)).astype(np.int32)])
        for l in lens]

    def make_reqs(seed0):
        # heterogeneous decode budgets stagger the finishes, so second-wave
        # admits overlap live first-wave residents (prefix pages shareable)
        return [Request(prompt=p, max_new_tokens=GEN + 8 * (i % 4),
                        sampling=SamplingSpec(seed=seed0 + i))
                for i, p in enumerate(wl_prompts)]

    # warm the chunked-prefill executables every wave will hit
    for r in make_reqs(100):
        engine.submit(r)
    engine.drain()
    engine.pool.reset_stats()

    def _wave(eng):
        """One timed continuous-batching wave: first B requests in flight,
        the rest admitted as pages free.  Every wave serves the same
        prompts and seeds, so wave digests must all match."""
        reqs = make_reqs(0)
        for r in reqs[:B]:
            eng.submit(r)
        eng.step()                     # first wave in flight
        t0 = time.perf_counter()
        for r in reqs[B:]:
            eng.submit(r)              # second wave admitted as pages free
        res = eng.drain()
        return res, time.perf_counter() - t0

    # the warmup's observations would pollute the continuous percentiles:
    # reset the registry so serve_* histograms hold only the timed wave
    Om.REGISTRY.reset()
    if args.trace:
        Otr.enable()
    results, t_cb = _wave(engine)
    cb_toks = sum(len(r.tokens) for r in results)
    cb_tps = cb_toks / max(t_cb, 1e-9)
    mean_tpot = float(np.mean([r.tpot_s for r in results]))
    mean_ttft = float(np.mean([r.ttft_s for r in results]))
    # continuous-section tail latency, straight from the obs histograms
    # the engine recorded during the timed wave (log-bucket interpolation,
    # obs/metrics.Histogram.quantile)
    h_ttft = Om.REGISTRY.get("serve_ttft_seconds")
    h_tpot = Om.REGISTRY.get("serve_tpot_seconds")
    cont_ttft_p50, cont_ttft_p95 = h_ttft.quantile(0.5), h_ttft.quantile(0.95)
    cont_tpot_p50, cont_tpot_p95 = h_tpot.quantile(0.5), h_tpot.quantile(0.95)

    # ---- instrumentation overhead: metrics-on vs metrics-off -------------
    # One extra wave per arm on the SAME warm engine, trace off in both so
    # the comparison isolates the metrics layer.  Comparing fresh-vs-fresh
    # within one process is far less noisy than fresh-vs-baseline; the
    # perf gate holds tps_on >= tps_off * (1 - 3%).  Two off waves and the
    # max() guard against a single slow outlier run.
    trace_was = Otr.TRACE.enabled
    Otr.TRACE.disable()
    res_on2, t_on2 = _wave(engine)
    Om.disable()
    res_off1, t_off1 = _wave(engine)
    res_off2, t_off2 = _wave(engine)
    Om.enable()
    if trace_was:
        Otr.TRACE.enable()
    for extra in (res_on2, res_off1, res_off2):
        assert _digest(extra) == _digest(results), \
            "instrumentation changed the token streams"
    tps_on = max(cb_tps,
                 sum(len(r.tokens) for r in res_on2) / max(t_on2, 1e-9))
    tps_off = max(sum(len(r.tokens) for r in res_off1) / max(t_off1, 1e-9),
                  sum(len(r.tokens) for r in res_off2) / max(t_off2, 1e-9))
    row("serving_metrics_overhead", max(0.0, 1 - tps_on / tps_off) * 1e6,
        f"on={tps_on:.1f};off={tps_off:.1f}tok/s")

    # ---- open-loop Poisson arrivals: tail latency under load -------------
    # Seeded interarrival gaps make the arrival SCHEDULE deterministic; the
    # wall clock (not engine progress) owns admission, so queueing shows up
    # in the TTFT tail.  Token streams are schedule-independent (per-slot
    # PRNG keys), so the digest must equal the continuous section's.
    gaps = np.random.default_rng(7).exponential(scale=POISSON_GAP_S,
                                                size=len(wl_prompts))
    arrivals = np.cumsum(gaps)
    pois_reqs = make_reqs(0)           # same tokens/seeds as every section
    pois_results = []
    t0 = time.perf_counter()
    i = 0
    while i < len(pois_reqs) or engine._queue or engine.pool.active_slots():
        now = time.perf_counter() - t0
        while i < len(pois_reqs) and arrivals[i] <= now:
            engine.submit(pois_reqs[i], submit_time=t0 + arrivals[i])
            i += 1
        if not (engine._queue or engine.pool.active_slots()):
            time.sleep(max(0.0, arrivals[i] - (time.perf_counter() - t0)))
            continue
        pois_results.extend(engine.step())
    t_pois = time.perf_counter() - t0
    ttfts = [r.ttft_s for r in pois_results]
    tpots = [r.tpot_s for r in pois_results if len(r.tokens) > 1]
    ttft_p50, ttft_p95 = (float(x) for x in np.percentile(ttfts, [50, 95]))
    tpot_p50, tpot_p95 = (float(x) for x in np.percentile(tpots, [50, 95]))
    pois_match = _digest(pois_results) == _digest(results)
    row("serving_poisson", t_pois / max(len(pois_results), 1) * 1e6,
        f"p95ttft={ttft_p95:.3f}s;gap={POISSON_GAP_S}s;match={pois_match}")

    # ---- async streaming front-end: AsyncEngine over the same engine -----
    # dispatch_depth 2 keeps a decode step in flight (host sync off the
    # critical path); streamed tokens must stay digest-identical to the
    # synchronous drain above — the bit-identity acceptance gate.
    engine.dispatch_depth = 2

    async def _stream_wave():
        front = AsyncEngine(engine)
        sessions = []
        for i, r in enumerate(make_reqs(0)):
            sessions.append(await front.submit(
                r.prompt, r.max_new_tokens, sampling=r.sampling))
            if i == B - 1:
                await asyncio.sleep(0.01)    # stagger the second wave
        out = [await s.result() for s in sessions]
        await front.close()
        return out

    t0 = time.perf_counter()
    stream_results = asyncio.run(_stream_wave())
    t_st = time.perf_counter() - t0
    engine.dispatch_depth = 1
    st_toks = sum(len(r.tokens) for r in stream_results)
    st_tps = st_toks / max(t_st, 1e-9)
    stream_match = _digest(stream_results) == _digest(results)
    stream_mean_ttft = float(np.mean([r.ttft_s for r in stream_results]))
    row("serving_stream", t_st / max(st_toks, 1) * 1e6,
        f"{st_tps:.1f}tok/s;depth=2;match={stream_match}")

    # ---- speculative decoding: same workload, draft/verify path ----------
    spec_json = {}
    spec_cfg = None
    if args.spec:
        if args.spec_provider == "tree":
            dcfg, dparams, agree = _distill_draft(cfg, params)
            fanout = (tuple(int(f) for f in args.spec_fanout.split(","))
                      if args.spec_fanout else ())
            spec_cfg = SpecConfig(k=args.spec_k, provider="tree",
                                  draft_cfg=dcfg, draft_params=dparams,
                                  fanout=fanout)
        else:
            spec_cfg = SpecConfig(k=args.spec_k, provider="ngram")
        spec_eng = Engine(cfg, params, max_len=MAXLEN, capacity=B,
                          spec=spec_cfg)
        for r in make_reqs(100):       # warm the verify/chunk executables
            spec_eng.submit(r)
        spec_eng.drain()
        spec_eng.pool.reset_stats()
        spec_eng.spec_stats(reset=True)
        reqs = make_reqs(0)
        for r in reqs[:B]:
            spec_eng.submit(r)
        spec_eng.step()
        t0 = time.perf_counter()
        for r in reqs[B:]:
            spec_eng.submit(r)
        spec_results = spec_eng.drain()
        t_sp = time.perf_counter() - t0
        sp_toks = sum(len(r.tokens) for r in spec_results)
        sp_tps = sp_toks / max(t_sp, 1e-9)
        proposed = sum(r.draft_proposed for r in spec_results)
        accepted = sum(r.draft_accepted for r in spec_results)
        sstats = spec_eng.spec_stats()
        spec_json = {
            "spec_k": args.spec_k,
            "spec_provider": args.spec_provider,
            "spec_continuous_tok_s": round(sp_tps, 1),
            "spec_speedup": round(sp_tps / max(cb_tps, 1e-9), 3),
            "spec_acceptance_rate": round(accepted / max(proposed, 1), 4),
            "spec_mean_accepted_len": round(sstats["mean_accepted_len"], 3),
            "spec_accept_len_hist": sstats["accept_len_hist"],
            "spec_mean_tpot_s": round(float(np.mean(
                [r.tpot_s for r in spec_results])), 6),
            # greedy speculation is lossless: same streams, same digest
            "spec_outputs_match": _digest(spec_results) == _digest(results),
        }
        if args.spec_provider == "tree":
            spec_json.update({
                "spec_fanout": sstats["fanout"],
                "spec_tree_nodes": sstats["tree_nodes"],
                "spec_offspine_accepted": sstats["offspine_accepted"],
                "spec_offspine_hist": sstats["offspine_hist"],
                "spec_distill_steps": DISTILL_STEPS,
                "spec_draft_agree": round(agree, 4),
            })
        row("serving_spec", t_sp / max(sp_toks, 1) * 1e6,
            f"{sp_tps:.1f}tok/s;k={args.spec_k};"
            f"provider={args.spec_provider};"
            f"accept={spec_json['spec_acceptance_rate']:.0%};"
            f"match={spec_json['spec_outputs_match']}")

    # ---- paged-vs-slot-contiguous memory accounting ----------------------
    st = engine.stats()
    page_b = st.kv_bytes_per_page
    max_pages = engine.pool.max_pages
    mean_pages = float(np.mean([r.pages_used for r in results]))
    kv_paged = mean_pages * page_b
    kv_slot = max_pages * page_b          # contiguous: full max_len rows
    used_rows = [r.prompt_len + len(r.tokens) - 1 for r in results]
    tail_frac = float(np.mean([1.0 - u / MAXLEN for u in used_rows]))
    # a paged pool reclaims whole pages: the page-granular tail is the
    # reduction floor the paged layout must meet (and does, exactly —
    # prefix sharing pushes the effective number below it)
    b = st.page_size
    tail_pages = float(np.mean(
        [1.0 - (-(-u // b)) * b / MAXLEN for u in used_rows]))
    reduction = 1.0 - kv_paged / kv_slot
    conc_slot = B                         # one max_len reservation per slot
    conc_paged = int(B * max_pages // max(mean_pages, 1.0))

    # ---- quantized KV pages: same workload, int8 pool ---------------------
    int8_json = {}
    if args.kv_dtype == "int8":
        eng8 = Engine(cfg, params, max_len=MAXLEN, capacity=B,
                      kv_dtype="int8")
        for r in make_reqs(100):
            eng8.submit(r)
        eng8.drain()
        eng8.pool.reset_stats()
        reqs8 = make_reqs(0)
        for r in reqs8[:B]:
            eng8.submit(r)
        eng8.step()
        t0 = time.perf_counter()
        for r in reqs8[B:]:
            eng8.submit(r)
        results8 = eng8.drain()
        t_8 = time.perf_counter() - t0
        tps8 = sum(len(r.tokens) for r in results8) / max(t_8, 1e-9)
        page_b8 = eng8.stats().kv_bytes_per_page
        mean_pages8 = float(np.mean([r.pages_used for r in results8]))
        kv_int8 = mean_pages8 * page_b8
        # same-HBM concurrency: the f32 slot-contiguous byte budget over
        # the int8 mean per-request footprint — the ceiling the
        # compressed pool raises (vs conc_paged on the same budget)
        conc_int8 = int(B * max_pages * page_b // max(kv_int8, 1.0))
        # quality: teacher-forced NLL of the f32 streams through the int8
        # paged path vs the f32 path (positive delta = int8 is worse)
        nll_f = nll_8 = 0.0
        base_id = min(r.request_id for r in results)
        scored = results[:4]
        for r in scored:
            prompt = wl_prompts[r.request_id - base_id]
            nll_f += -float(np.mean(engine.score(prompt, r.tokens)))
            nll_8 += -float(np.mean(eng8.score(prompt, r.tokens)))
        nll_delta = (nll_8 - nll_f) / len(scored)
        int8_json = {
            "kv_dtype": "int8",
            "int8_continuous_tok_s": round(tps8, 1),
            "kv_bytes_per_request_int8": round(kv_int8),
            "max_concurrency_int8": conc_int8,
            "int8_nll_delta": round(nll_delta, 5),
        }
        if args.spec:
            # same provider (and distilled draft) as the f32 spec section:
            # the int8 acceptance rate isolates quantization, not the draft
            spec8 = Engine(cfg, params, max_len=MAXLEN, capacity=B,
                           kv_dtype="int8", spec=spec_cfg)
            for r in make_reqs(100):
                spec8.submit(r)
            spec8.drain()
            for r in make_reqs(0):
                spec8.submit(r)
            sres8 = spec8.drain()
            prop8 = sum(r.draft_proposed for r in sres8)
            acc8 = sum(r.draft_accepted for r in sres8)
            int8_json["spec_acceptance_rate_int8"] = round(
                acc8 / max(prop8, 1), 4)
        row("serving_int8", t_8 / max(sum(len(r.tokens) for r in results8),
                                      1) * 1e6,
            f"{tps8:.1f}tok/s;{kv_int8:.0f}B/req;"
            f"conc={conc_int8};dnll={nll_delta:.4f}")

    # ---- host-memory swap tier: starved pool, digest-gated ----------------
    swap_json = {}
    if args.host_swap:
        # largest request needs ceil((32 + 255 + 47) / 32) = 11 pages;
        # 16 total (15 usable) is under half the unswapped peak working
        # set (~33), so the workload only fits through the host tier
        eng_sw = Engine(cfg, params, max_len=MAXLEN, capacity=B,
                        host_swap=True, num_pages=16)
        for r in make_reqs(100):
            eng_sw.submit(r)
        eng_sw.drain()
        eng_sw.pool.reset_stats()
        reqs_sw = make_reqs(0)
        for r in reqs_sw[:B]:
            eng_sw.submit(r)
        eng_sw.step()
        t0 = time.perf_counter()
        for r in reqs_sw[B:]:
            eng_sw.submit(r)
        results_sw = eng_sw.drain()
        t_sw = time.perf_counter() - t0
        tps_sw = sum(len(r.tokens) for r in results_sw) / max(t_sw, 1e-9)
        st_sw = eng_sw.stats()
        swap_json = {
            "swap_num_pages": eng_sw.pool.num_pages,
            "swap_tok_s": round(tps_sw, 1),
            "swap_out_total": st_sw.swap_out,
            "swap_in_total": st_sw.swap_in,
            "pages_host_peak": eng_sw.pool.pages_host_peak,
            # the swap tier is exact: same streams as the ample pool
            "swap_outputs_match": _digest(results_sw) == _digest(results),
        }
        row("serving_swap", t_sw / max(sum(len(r.tokens)
                                           for r in results_sw), 1) * 1e6,
            f"{tps_sw:.1f}tok/s;pages={eng_sw.pool.num_pages};"
            f"out={st_sw.swap_out};in={st_sw.swap_in};"
            f"match={swap_json['swap_outputs_match']}")

    obs_json = {}
    if args.trace:
        n_ev = Otr.dump(args.trace)
        obs_json["trace_events"] = n_ev
        print(f"# trace: wrote {n_ev} events to {args.trace}")
    if mserver is not None:
        import urllib.request
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{mserver.port}/metrics",
                    timeout=5) as resp:
                body = resp.read().decode()
            obs_json["metrics_endpoint_ok"] = (
                resp.status == 200 and "serve_ttft_seconds_bucket" in body)
        except Exception:
            obs_json["metrics_endpoint_ok"] = False
        mserver.shutdown()

    row("serving_ttft", ttft * 1e6, f"B{B}xS{PROMPT}")
    row("serving_decode", (t_gen - ttft) / dec_steps * 1e6,
        f"{dec_tps:.1f}tok/s")
    row("serving_continuous", t_cb / max(cb_toks, 1) * 1e6,
        f"{cb_tps:.1f}tok/s;mesh={mesh_name}")
    row("serving_kv_bytes_req", kv_paged,
        f"paged;slot={kv_slot:.0f};-{reduction * 100:.0f}%")
    row("serving_concurrency", conc_paged,
        f"paged-vs-slot={conc_slot};same-HBM")
    print("SERVING_JSON " + json.dumps({
        "batch": B, "prompt_len": PROMPT, "gen": GEN, "max_len": MAXLEN,
        "mesh": mesh_name,
        "data_shards": st.data_shards,
        "ttft_s": round(ttft, 4),
        "decode_tok_s": round(dec_tps, 1),
        "continuous_tok_s": round(cb_tps, 1),
        "continuous_requests": len(results),
        "mean_ttft_s": round(mean_ttft, 6),
        "mean_tpot_s": round(mean_tpot, 6),
        "continuous_ttft_p50_s": round(cont_ttft_p50, 6),
        "continuous_ttft_p95_s": round(cont_ttft_p95, 6),
        "continuous_tpot_p50_s": round(cont_tpot_p50, 6),
        "continuous_tpot_p95_s": round(cont_tpot_p95, 6),
        "continuous_tok_s_metrics_on": round(tps_on, 1),
        "continuous_tok_s_metrics_off": round(tps_off, 1),
        "metrics_overhead_frac": round(max(0.0, 1 - tps_on / tps_off), 4),
        "ragged_prefill": engine._ragged,
        "poisson_gap_s": POISSON_GAP_S,
        "poisson_requests": len(pois_results),
        "ttft_p50_s": round(ttft_p50, 6),
        "ttft_p95_s": round(ttft_p95, 6),
        "tpot_p50_s": round(tpot_p50, 6),
        "tpot_p95_s": round(tpot_p95, 6),
        "poisson_outputs_match": pois_match,
        "stream_tok_s": round(st_tps, 1),
        "stream_mean_ttft_s": round(stream_mean_ttft, 6),
        "stream_outputs_match": stream_match,
        "outputs_digest": _digest(results),
        **spec_json,
        **int8_json,
        **swap_json,
        **obs_json,
        "page_size": st.page_size,
        "kv_bytes_per_request_paged": round(kv_paged),
        "kv_bytes_per_request_slot": round(kv_slot),
        "kv_bytes_per_shard": st.kv_bytes_per_shard,
        "kv_reduction": round(reduction, 4),
        "unused_tail_frac": round(tail_frac, 4),
        "unused_tail_frac_pages": round(tail_pages, 4),
        "max_concurrency_paged": conc_paged,
        "max_concurrency_slot": conc_slot,
        "prefix_hits": st.prefix_hits,
        "prefix_pages_shared": st.prefix_pages_shared,
        "peak_pages_in_use": st.peak_pages_in_use,
        "peak_pages_per_shard": st.peak_pages_per_shard,
    }))


if __name__ == "__main__":
    main()
