"""Serving benchmark: time-to-first-token + decode tok/s on the Engine.

Three measurements over a small BigBird LM (bounded decode):
  serving_ttft          — warm prefill + first sampled token (generate(1));
  serving_decode        — steady-state jitted-loop decode tok/s;
  serving_continuous    — slot-batched throughput with staggered admits and
                          heterogeneous prompt lengths.

Prints the standard `name,us_per_call,derived` CSV rows plus one JSON line
(`SERVING_JSON {...}`) for the bench trajectory.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.attention import AttentionSpec
from repro.models import model as M
from repro.serve import Engine, Request, SamplingSpec

B, PROMPT, GEN, MAXLEN = 4, 256, 24, 512


def _build():
    bigbird = AttentionSpec(kind="bigbird", causal=True, block_size=32,
                            num_window_blocks=3, num_global_blocks=1,
                            num_random_blocks=1, impl="blockified")
    cfg = M.ModelConfig(name="bench-serve", d_model=128, num_layers=4,
                        num_heads=4, num_kv_heads=2, d_ff=512,
                        vocab_size=1024, attn=bigbird, dtype=jnp.float32,
                        scan_layers=False, remat="none", loss_chunk=128)
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def main():
    cfg, params = _build()
    engine = Engine(cfg, params, max_len=MAXLEN, capacity=B)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size, size=PROMPT).astype(np.int32)
               for _ in range(B)]

    # warm every executable first (compile excluded from all timings)
    engine.generate(prompts, max_new=1)
    engine.generate(prompts, max_new=GEN)

    t0 = time.perf_counter()
    engine.generate(prompts, max_new=1)
    ttft = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine.generate(prompts, max_new=GEN)
    t_gen = time.perf_counter() - t0
    dec_steps = GEN - 1
    dec_toks = B * dec_steps
    dec_tps = dec_toks / max(t_gen - ttft, 1e-9)

    # continuous batching: 2x oversubscribed, staggered, ragged prompts
    lens = rng.integers(PROMPT // 4, PROMPT, size=2 * B)
    reqs = [Request(prompt=rng.integers(4, cfg.vocab_size,
                                        size=int(l)).astype(np.int32),
                    max_new_tokens=GEN, sampling=SamplingSpec(seed=i))
            for i, l in enumerate(lens)]
    # warm every B=1 prefill bucket BOTH waves will hit (the second wave is
    # admitted inside the timed region)
    for sb in sorted({engine.bucket_len(int(l)) for l in lens}):
        engine.generate([np.full((sb,), 5, np.int32)], max_new=1)
    for r in reqs[:B]:
        engine.submit(r)
    engine.step()                      # first wave in flight
    t0 = time.perf_counter()
    for r in reqs[B:]:
        engine.submit(r)               # second wave admitted as slots free
    results = engine.drain()
    t_cb = time.perf_counter() - t0
    cb_toks = sum(len(r.tokens) for r in results)
    cb_tps = cb_toks / max(t_cb, 1e-9)

    row("serving_ttft", ttft * 1e6, f"B{B}xS{PROMPT}")
    row("serving_decode", (t_gen - ttft) / dec_steps * 1e6,
        f"{dec_tps:.1f}tok/s")
    row("serving_continuous", t_cb / max(cb_toks, 1) * 1e6,
        f"{cb_tps:.1f}tok/s")
    print("SERVING_JSON " + json.dumps({
        "batch": B, "prompt_len": PROMPT, "gen": GEN, "max_len": MAXLEN,
        "ttft_s": round(ttft, 4),
        "decode_tok_s": round(dec_tps, 1),
        "continuous_tok_s": round(cb_tps, 1),
        "continuous_requests": len(results),
    }))


if __name__ == "__main__":
    main()
