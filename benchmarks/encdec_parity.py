"""Paper Sec. 4.1 / Tables 4 & 20 — sparse encoder does not hamper seq2seq.

Trains the same tiny encoder-decoder on lead-summarization (summary = the
document's lead span; Tab. 20's "Lead" baseline task) with (a) full encoder
attention and (b) BigBird encoder + full decoder (the paper's recipe).

Derived: final held-out teacher-forced loss of both; parity gap.  The
paper's claim is sparse ~= full at equal length (and sparse enables longer
inputs at the same cost).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core.attention import AttentionSpec
from repro.launch import steps as S
from repro.models import model as M

STEPS = 400
S_ENC, S_DEC, V, BOS = 128, 16, 256, 5


def make_batch(step, B=16):
    rng = np.random.default_rng(step)
    doc = rng.integers(8, V, size=(B, S_ENC)).astype(np.int32)
    tgt = doc[:, :S_DEC]
    dec_in = np.concatenate([np.full((B, 1), BOS), tgt[:, :-1]],
                            axis=1).astype(np.int32)
    return doc, dec_in, tgt


def train(enc_attn):
    cfg = M.ModelConfig(
        name="parity", kind="encdec", d_model=64, num_layers=2, enc_layers=2,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=V,
        dec_len=S_DEC, enc_attn=enc_attn, dtype=jnp.float32,
        scan_layers=False, remat="none", loss_chunk=16, frontend="audio")
    opt = S.make_optimizer(schedule="constant", peak_lr=5e-3)
    ts = jax.jit(S.make_train_step(cfg, opt), donate_argnums=(0,))
    params = M.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    def batch_of(step):
        doc, dec_in, tgt = make_batch(step)
        frames = jnp.take(state["params"]["embed"]["table"],
                          jnp.asarray(doc), axis=0)
        return {"frames": frames, "tokens": jnp.asarray(dec_in),
                "labels": jnp.asarray(tgt)}

    for step in range(STEPS):
        state, m = ts(state, batch_of(step))
    ev = 0.0
    for step in range(900_000, 900_004):
        ev += float(M.loss_fn(state["params"], cfg, batch_of(step)))
    return ev / 4


def main():
    full = AttentionSpec(kind="full", causal=False)
    sparse = AttentionSpec(kind="bigbird", causal=False, block_size=16,
                           num_window_blocks=3, num_global_blocks=1,
                           num_random_blocks=1, impl="blockified")
    lf = train(full)
    ls = train(sparse)
    row("encdec_full_encoder", 0.0, f"heldout_loss={lf:.4f}")
    row("encdec_bigbird_encoder", 0.0, f"heldout_loss={ls:.4f}")
    row("encdec_parity_gap", 0.0,
        f"gap={ls-lf:+.4f},parity={abs(ls-lf) < 0.35}")
    return lf, ls


if __name__ == "__main__":
    main()
