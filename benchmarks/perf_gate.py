"""CI perf gate: diff a fresh SERVING_JSON against the committed baseline.

    python benchmarks/perf_gate.py --fresh bench-serving.txt \
        [--baseline benchmarks/baselines/BENCH_serving.json]

Two classes of metric, gated differently:

* STRUCTURAL metrics (page accounting) are deterministic functions of the
  engine code, independent of machine speed, and gate HARD: any growth of
  `kv_bytes_per_request_paged` beyond 1%, or a change of `page_size` /
  `max_concurrency_paged` / `kv_reduction`, fails the build.  A memory
  regression in the paged pool cannot hide behind a fast runner.
* TIMING metrics (ttft_s, decode_tok_s, continuous_tok_s,
  spec_continuous_tok_s) gate on wide relative bands (default 4x),
  because shared CI runners are noisy; the bands catch order-of-magnitude
  regressions (a de-jitted hot loop, an accidental recompile per token)
  without flaking on scheduler jitter.
* SCHEDULING latency (mean_ttft_s over the continuous workload, ttft_p95_s
  over the Poisson workload) gates at HALF the timing band: these average
  over the whole workload, so they are far less jittery than single-shot
  timings, and they are exactly the numbers the ragged-prefill + async
  front-end work exists to hold down — losing the ~2x TTFT win must not
  hide inside the wide band.

Bit-identity gates (active once the baseline carries the fields): the
async streaming front-end (`stream_outputs_match`) and the open-loop
Poisson schedule (`poisson_outputs_match`) must reproduce the synchronous
drain's token streams exactly — false means scheduling changed model
outputs, a correctness bug no timing band excuses.

Speculative-decoding metrics (benchmarks/serving.py --spec) gate on both
sides: `spec_outputs_match` must stay true (greedy speculation is
lossless BY CONSTRUCTION — a false here means accepted tokens diverged
from the vanilla stream, a correctness bug no timing band should excuse),
and `spec_acceptance_rate` may not fall below
max(base − ACCEPT_DROP_TOL, base · ACCEPT_REL_FLOOR) (the draft pipeline
silently proposing garbage is a real regression even when wall-clock
stays inside the wide band).  Spec fields
are gated only when the baseline carries them.

Exit code 0 = within bands, 1 = regression, 2 = usage/parse error.

Re-baselining: land the new numbers in
`benchmarks/baselines/BENCH_serving.json` in the same PR; put
`[bench-baseline]` in the HEAD commit's message to skip the gate for that
run (the CI workflow checks exactly the commit under test, so the escape
hatch cannot leak to later runs).
"""

from __future__ import annotations

import argparse
import json
import sys

BASELINE = "benchmarks/baselines/BENCH_serving.json"

STRUCTURAL_EXACT = ("page_size", "max_concurrency_paged", "kv_reduction")
KV_GROWTH_TOL = 0.01  # hard gate: paged KV bytes/request may grow <= 1%
ACCEPT_DROP_TOL = 0.15   # spec acceptance may drop <= 15 points absolute...
ACCEPT_REL_FLOOR = 0.5   # ...but never below half the baseline rate (the
#                          absolute band alone is vacuous for small baselines)


def parse_serving_json(text: str) -> dict:
    """Extract the SERVING_JSON payload from benchmark output (or accept a
    bare JSON document, for pre-extracted baselines)."""
    for line in text.splitlines():
        if line.startswith("SERVING_JSON "):
            return json.loads(line[len("SERVING_JSON "):])
    return json.loads(text)


def check(fresh: dict, base: dict, timing_band: float) -> list:
    """Compare fresh vs baseline; returns a list of violation strings."""
    bad = []

    kv_f = fresh["kv_bytes_per_request_paged"]
    kv_b = base["kv_bytes_per_request_paged"]
    if kv_f > kv_b * (1.0 + KV_GROWTH_TOL):
        bad.append(
            f"kv_bytes_per_request_paged grew {kv_b} -> {kv_f} "
            f"(hard gate: <= {KV_GROWTH_TOL:.0%})"
        )
    for key in STRUCTURAL_EXACT:
        if fresh.get(key) != base.get(key):
            bad.append(f"{key} changed {base.get(key)} -> {fresh.get(key)}")

    if fresh["ttft_s"] > base["ttft_s"] * timing_band:
        bad.append(
            f"ttft_s {fresh['ttft_s']} vs baseline {base['ttft_s']} "
            f"(band {timing_band}x)"
        )
    for key in ("decode_tok_s", "continuous_tok_s"):
        if fresh[key] * timing_band < base[key]:
            bad.append(
                f"{key} {fresh[key]} vs baseline {base[key]} "
                f"(band {timing_band}x)"
            )

    # scheduling latency: workload aggregates, tighter half-band
    tail_band = max(1.0, timing_band / 2.0)
    for key in ("mean_ttft_s", "ttft_p95_s"):
        if key in base and fresh.get(key, 0.0) > base[key] * tail_band:
            bad.append(
                f"{key} {fresh.get(key)} vs baseline {base[key]} "
                f"(band {tail_band}x: ragged prefill / front-end "
                f"scheduling regression)"
            )

    # scheduling must never change model outputs
    for key in ("stream_outputs_match", "poisson_outputs_match"):
        if key in base and fresh.get(key) is not True:
            bad.append(
                f"{key} is not true: scheduled token streams diverged "
                "from the synchronous drain (bit-identity correctness "
                "bug, not a perf regression)"
            )

    # speculative-decoding gates, active once the baseline carries them
    if "spec_acceptance_rate" in base:
        if "spec_acceptance_rate" not in fresh:
            bad.append(
                "spec metrics missing from fresh run "
                "(benchmarks/serving.py must run with --spec)"
            )
            return bad
        if fresh.get("spec_outputs_match") is not True:
            bad.append(
                "spec_outputs_match is not true: greedy speculative decode "
                "diverged from the vanilla token streams (lossless-"
                "acceptance correctness bug, not a perf regression)"
            )
        a_f, a_b = fresh["spec_acceptance_rate"], base["spec_acceptance_rate"]
        floor = max(a_b - ACCEPT_DROP_TOL, a_b * ACCEPT_REL_FLOOR)
        if a_f < floor:
            bad.append(
                f"spec_acceptance_rate dropped {a_b} -> {a_f} "
                f"(floor {floor:.4f}: -{ACCEPT_DROP_TOL} absolute, "
                f"x{ACCEPT_REL_FLOOR} relative)"
            )
        if fresh["spec_continuous_tok_s"] * timing_band < \
                base["spec_continuous_tok_s"]:
            bad.append(
                f"spec_continuous_tok_s {fresh['spec_continuous_tok_s']} vs "
                f"baseline {base['spec_continuous_tok_s']} "
                f"(band {timing_band}x)"
            )
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="benchmark output file")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument(
        "--timing-band",
        type=float,
        default=4.0,
        help="allowed relative slowdown for timing metrics (default 4x)",
    )
    args = ap.parse_args(argv)

    try:
        with open(args.fresh) as f:
            fresh = parse_serving_json(f.read())
        with open(args.baseline) as f:
            base = parse_serving_json(f.read())
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"perf-gate: cannot load inputs: {e}")
        return 2

    try:
        bad = check(fresh, base, args.timing_band)
    except KeyError as e:
        print(f"perf-gate: metric missing from SERVING_JSON: {e}")
        return 2
    if bad:
        print("perf-gate: REGRESSION vs", args.baseline)
        for v in bad:
            print("  -", v)
        print(
            "re-baseline intentionally: update the baseline file and push "
            "with [bench-baseline] in the commit message"
        )
        return 1
    print(
        f"perf-gate: OK (kv {fresh['kv_bytes_per_request_paged']}B/req, "
        f"ttft {fresh['ttft_s']}s, decode {fresh['decode_tok_s']} tok/s, "
        f"continuous {fresh['continuous_tok_s']} tok/s, "
        f"mean_ttft {fresh.get('mean_ttft_s')}s, "
        f"p95_ttft {fresh.get('ttft_p95_s')}s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
