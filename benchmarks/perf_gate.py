"""CI perf gate: diff a fresh SERVING_JSON against the committed baseline.

    python benchmarks/perf_gate.py --fresh bench-serving.txt \
        [--baseline benchmarks/baselines/BENCH_serving.json]

Two classes of metric, gated differently:

* STRUCTURAL metrics (page accounting) are deterministic functions of the
  engine code, independent of machine speed, and gate HARD: any growth of
  `kv_bytes_per_request_paged` beyond 1%, or a change of `page_size` /
  `max_concurrency_paged` / `kv_reduction`, fails the build.  A memory
  regression in the paged pool cannot hide behind a fast runner.
* TIMING metrics (ttft_s, decode_tok_s, continuous_tok_s,
  spec_continuous_tok_s) gate on wide relative bands (default 4x),
  because shared CI runners are noisy; the bands catch order-of-magnitude
  regressions (a de-jitted hot loop, an accidental recompile per token)
  without flaking on scheduler jitter.
* SCHEDULING latency (mean_ttft_s over the continuous workload, ttft_p95_s
  over the Poisson workload) gates at HALF the timing band: these average
  over the whole workload, so they are far less jittery than single-shot
  timings, and they are exactly the numbers the ragged-prefill + async
  front-end work exists to hold down — losing the ~2x TTFT win must not
  hide inside the wide band.

Bit-identity gates (active once the baseline carries the fields): the
async streaming front-end (`stream_outputs_match`) and the open-loop
Poisson schedule (`poisson_outputs_match`) must reproduce the synchronous
drain's token streams exactly — false means scheduling changed model
outputs, a correctness bug no timing band excuses.

Speculative-decoding metrics (benchmarks/serving.py --spec) gate on both
sides: `spec_outputs_match` must stay true (greedy speculation is
lossless BY CONSTRUCTION — a false here means accepted tokens diverged
from the vanilla stream, a correctness bug no timing band should excuse),
and `spec_acceptance_rate` gates per provider: the statistical ngram
draft keeps the loose band max(base − ACCEPT_DROP_TOL,
base · ACCEPT_REL_FLOOR), while trained drafts (`spec_provider`
"tree"/"model") must clear the hard absolute TRAINED_ACCEPT_FLOOR
(≥ 0.35) — a band around a small baseline would pass a draft that
accepts nothing, and a distilled draft below the floor has lost its
training signal even when wall-clock stays inside the wide band.  Spec
fields are gated only when the baseline carries them.

KV-compression metrics (benchmarks/serving.py --kv-dtype int8,
--host-swap), gated once the baseline carries them:

* `swap_outputs_match` gates HARD: the host-swap tier is exact by
  construction (pages round-trip bitwise through host memory), so the
  swapped run's digest must equal the unswapped run's — and
  `swap_out_total` must stay positive, else the swap path silently went
  dormant and the equality is vacuous.
* int8 pages are lossy, so they gate on QUALITY, not bits:
  `int8_nll_delta` (mean teacher-forced NLL inflation of the f32 streams
  under the int8 engine) must stay under
  max(INT8_NLL_ABS_CEIL, 2·|baseline|), and `spec_acceptance_rate_int8`
  keeps the same acceptance floor as the f32 spec path — quantization
  that breaks the draft/verify contract is a regression wherever the
  wall-clock lands.
* the structural side of compression gates like the other page
  accounting: `kv_bytes_per_request_int8` may grow <= 1%, and
  `max_concurrency_int8` is exact AND must stay strictly above
  `max_concurrency_paged` (a compressed pool that cannot outpack the
  uncompressed one has lost its reason to exist).

Observability overhead (active once the baseline carries
`continuous_tok_s_metrics_off`): the bench times the same continuous
wave with the metrics registry on and off IN THE SAME RUN, and the
instrumented arm must hold >= (1 - METRICS_OVERHEAD_TOL) of the
disabled arm's tok/s.  Fresh-vs-fresh, so runner speed cancels — this
is a hard gate on the cost of obs/, not a noisy timing band.

Exit code 0 = within bands, 1 = regression, 2 = usage/parse error.

Re-baselining: land the new numbers in
`benchmarks/baselines/BENCH_serving.json` in the same PR; put
`[bench-baseline]` in the HEAD commit's message to skip the gate for that
run (the CI workflow checks exactly the commit under test, so the escape
hatch cannot leak to later runs).
"""

from __future__ import annotations

import argparse
import json
import sys

BASELINE = "benchmarks/baselines/BENCH_serving.json"

STRUCTURAL_EXACT = ("page_size", "max_concurrency_paged", "kv_reduction")
KV_GROWTH_TOL = 0.01  # hard gate: paged KV bytes/request may grow <= 1%
ACCEPT_DROP_TOL = 0.15   # spec acceptance may drop <= 15 points absolute...
ACCEPT_REL_FLOOR = 0.5   # ...but never below half the baseline rate (the
#                          absolute band alone is vacuous for small baselines)
TRAINED_ACCEPT_FLOOR = 0.35  # hard absolute floor for trained drafts
#                          (spec_provider "tree"/"model"): a distilled draft
#                          that stops clearing 35% has lost its training
#                          signal, wherever the baseline sat.  The loose
#                          band above applies only to the statistical ngram
#                          provider, whose baseline is legitimately small.
INT8_NLL_ABS_CEIL = 0.1  # int8 NLL inflation ceiling (nats/token), floor of
#                          the relative band 2x|baseline| for tiny baselines
METRICS_OVERHEAD_TOL = 0.03  # metrics-on continuous tok/s must stay within
#                          3% of metrics-off.  Both arms come from the SAME
#                          fresh run (benchmarks/serving.py times extra
#                          waves with the registry disabled), so this gate
#                          compares fresh-vs-fresh and is immune to runner
#                          speed — it is a hard ceiling on what the
#                          observability layer may cost, not a timing band.


def parse_serving_json(text: str) -> dict:
    """Extract the SERVING_JSON payload from benchmark output (or accept a
    bare JSON document, for pre-extracted baselines)."""
    for line in text.splitlines():
        if line.startswith("SERVING_JSON "):
            return json.loads(line[len("SERVING_JSON "):])
    return json.loads(text)


def check(fresh: dict, base: dict, timing_band: float) -> list:
    """Compare fresh vs baseline; returns a list of violation strings."""
    bad = []

    kv_f = fresh["kv_bytes_per_request_paged"]
    kv_b = base["kv_bytes_per_request_paged"]
    if kv_f > kv_b * (1.0 + KV_GROWTH_TOL):
        bad.append(
            f"kv_bytes_per_request_paged grew {kv_b} -> {kv_f} "
            f"(hard gate: <= {KV_GROWTH_TOL:.0%})"
        )
    for key in STRUCTURAL_EXACT:
        if fresh.get(key) != base.get(key):
            bad.append(f"{key} changed {base.get(key)} -> {fresh.get(key)}")

    if fresh["ttft_s"] > base["ttft_s"] * timing_band:
        bad.append(
            f"ttft_s {fresh['ttft_s']} vs baseline {base['ttft_s']} "
            f"(band {timing_band}x)"
        )
    for key in ("decode_tok_s", "continuous_tok_s"):
        if fresh[key] * timing_band < base[key]:
            bad.append(
                f"{key} {fresh[key]} vs baseline {base[key]} "
                f"(band {timing_band}x)"
            )

    # scheduling latency: workload aggregates, tighter half-band
    tail_band = max(1.0, timing_band / 2.0)
    for key in ("mean_ttft_s", "ttft_p95_s"):
        if key in base and fresh.get(key, 0.0) > base[key] * tail_band:
            bad.append(
                f"{key} {fresh.get(key)} vs baseline {base[key]} "
                f"(band {tail_band}x: ragged prefill / front-end "
                f"scheduling regression)"
            )

    # scheduling must never change model outputs
    for key in ("stream_outputs_match", "poisson_outputs_match"):
        if key in base and fresh.get(key) is not True:
            bad.append(
                f"{key} is not true: scheduled token streams diverged "
                "from the synchronous drain (bit-identity correctness "
                "bug, not a perf regression)"
            )

    # speculative-decoding gates, active once the baseline carries them
    if "spec_acceptance_rate" in base:
        if "spec_acceptance_rate" not in fresh:
            bad.append(
                "spec metrics missing from fresh run "
                "(benchmarks/serving.py must run with --spec)"
            )
            return bad
        if fresh.get("spec_outputs_match") is not True:
            bad.append(
                "spec_outputs_match is not true: greedy speculative decode "
                "diverged from the vanilla token streams (lossless-"
                "acceptance correctness bug, not a perf regression)"
            )
        a_f, a_b = fresh["spec_acceptance_rate"], base["spec_acceptance_rate"]
        prov = fresh.get("spec_provider", base.get("spec_provider", "ngram"))
        if prov == "ngram":
            # statistical draft: loose band around a legitimately small base
            floor = max(a_b - ACCEPT_DROP_TOL, a_b * ACCEPT_REL_FLOOR)
            if a_f < floor:
                bad.append(
                    f"spec_acceptance_rate dropped {a_b} -> {a_f} "
                    f"(floor {floor:.4f}: -{ACCEPT_DROP_TOL} absolute, "
                    f"x{ACCEPT_REL_FLOOR} relative)"
                )
        elif a_f < TRAINED_ACCEPT_FLOOR:
            # trained draft (tree/model): hard absolute floor — the loose
            # band around a 0.08 ngram baseline would pass a provider that
            # accepts nothing, which is exactly the regression that matters
            bad.append(
                f"spec_acceptance_rate {a_f} below the trained-draft "
                f"floor {TRAINED_ACCEPT_FLOOR} (provider={prov}: the "
                f"distilled draft no longer predicts the target)"
            )
        if fresh["spec_continuous_tok_s"] * timing_band < \
                base["spec_continuous_tok_s"]:
            bad.append(
                f"spec_continuous_tok_s {fresh['spec_continuous_tok_s']} vs "
                f"baseline {base['spec_continuous_tok_s']} "
                f"(band {timing_band}x)"
            )

    # metrics-overhead gate, active once the baseline carries the off arm:
    # within ONE fresh run, the instrumented continuous wave must hold
    # >= (1 - 3%) of the registry-disabled wave's throughput
    if "continuous_tok_s_metrics_off" in base:
        on = fresh.get("continuous_tok_s_metrics_on")
        off = fresh.get("continuous_tok_s_metrics_off")
        if on is None or off is None:
            bad.append(
                "metrics overhead arms missing from fresh run "
                "(continuous_tok_s_metrics_on/off: benchmarks/serving.py "
                "must time the metrics-off waves)"
            )
        elif on < off * (1.0 - METRICS_OVERHEAD_TOL):
            bad.append(
                f"metrics overhead: continuous_tok_s_metrics_on {on} vs "
                f"metrics_off {off} (hard gate: within "
                f"{METRICS_OVERHEAD_TOL:.0%} — the observability layer "
                f"got too expensive)"
            )

    # host-swap gates: the swap tier is exact by construction, so digest
    # equality gates HARD — and the swap path must actually have run
    if "swap_outputs_match" in base:
        if fresh.get("swap_outputs_match") is not True:
            bad.append(
                "swap_outputs_match is not true: host-swapped token "
                "streams diverged from the unswapped run (the swap tier "
                "is bitwise by construction — correctness bug, not perf)"
            )
        if not fresh.get("swap_out_total", 0) > 0:
            bad.append(
                "swap_out_total is 0: the starved-pool section produced "
                "no swap traffic, so swap_outputs_match gated nothing"
            )

    # int8 KV gates: lossy pages gate on quality + structure, not bits
    if "int8_nll_delta" in base:
        d_f, d_b = fresh["int8_nll_delta"], base["int8_nll_delta"]
        ceil = max(INT8_NLL_ABS_CEIL, 2.0 * abs(d_b))
        if d_f > ceil:
            bad.append(
                f"int8_nll_delta rose {d_b} -> {d_f} (ceiling {ceil:.4f}: "
                f"int8 KV pages degraded model quality)"
            )
        kv8_f = fresh["kv_bytes_per_request_int8"]
        kv8_b = base["kv_bytes_per_request_int8"]
        if kv8_f > kv8_b * (1.0 + KV_GROWTH_TOL):
            bad.append(
                f"kv_bytes_per_request_int8 grew {kv8_b} -> {kv8_f} "
                f"(hard gate: <= {KV_GROWTH_TOL:.0%})"
            )
        if fresh.get("max_concurrency_int8") != base.get(
                "max_concurrency_int8"):
            bad.append(
                f"max_concurrency_int8 changed "
                f"{base.get('max_concurrency_int8')} -> "
                f"{fresh.get('max_concurrency_int8')}"
            )
        if not fresh.get("max_concurrency_int8", 0) > \
                fresh.get("max_concurrency_paged", 0):
            bad.append(
                f"max_concurrency_int8 "
                f"({fresh.get('max_concurrency_int8')}) does not exceed "
                f"max_concurrency_paged "
                f"({fresh.get('max_concurrency_paged')}): the compressed "
                f"pool no longer raises the concurrency ceiling"
            )
        if "spec_acceptance_rate_int8" in base:
            a_f = fresh.get("spec_acceptance_rate_int8", 0.0)
            a_b = base["spec_acceptance_rate_int8"]
            floor = max(a_b - ACCEPT_DROP_TOL, a_b * ACCEPT_REL_FLOOR)
            if a_f < floor:
                bad.append(
                    f"spec_acceptance_rate_int8 dropped {a_b} -> {a_f} "
                    f"(floor {floor:.4f}: quantized verify path rejects "
                    f"drafts it used to accept)"
                )
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="benchmark output file")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument(
        "--timing-band",
        type=float,
        default=4.0,
        help="allowed relative slowdown for timing metrics (default 4x)",
    )
    args = ap.parse_args(argv)

    try:
        with open(args.fresh) as f:
            fresh = parse_serving_json(f.read())
        with open(args.baseline) as f:
            base = parse_serving_json(f.read())
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"perf-gate: cannot load inputs: {e}")
        return 2

    try:
        bad = check(fresh, base, args.timing_band)
    except KeyError as e:
        print(f"perf-gate: metric missing from SERVING_JSON: {e}")
        return 2
    if bad:
        print("perf-gate: REGRESSION vs", args.baseline)
        for v in bad:
            print("  -", v)
        print(
            "re-baseline intentionally: update the baseline file and push "
            "with [bench-baseline] in the commit message"
        )
        return 1
    print(
        f"perf-gate: OK (kv {fresh['kv_bytes_per_request_paged']}B/req, "
        f"ttft {fresh['ttft_s']}s, decode {fresh['decode_tok_s']} tok/s, "
        f"continuous {fresh['continuous_tok_s']} tok/s, "
        f"mean_ttft {fresh.get('mean_ttft_s')}s, "
        f"p95_ttft {fresh.get('ttft_p95_s')}s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
