"""Roofline table generator — reads experiments/dryrun_*.json (produced by
launch/dryrun.py) and emits the per-(arch x shape x mesh) roofline rows for
EXPERIMENTS.md §Roofline.  CSV derived column = dominant term + seconds.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

EXP = Path(__file__).resolve().parents[1] / "experiments"


def load(mesh="single"):
    p = EXP / f"dryrun_{mesh}.json"
    if not p.exists():
        return {}
    return json.loads(p.read_text())


def main():
    for mesh in ("single", "multi"):
        data = load(mesh)
        for key, rec in sorted(data.items()):
            if not rec.get("ok"):
                row(f"roofline_{mesh}_{key}", 0.0, "FAILED")
                continue
            r = rec["roofline"]
            ratio = rec.get("model_vs_hlo_flops")
            row(f"roofline_{mesh}_{key}", 0.0,
                f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                f"collective_s={r['collective_s']:.4f};dom={r['dominant']};"
                f"useful_flops_ratio={ratio if ratio is None else round(ratio, 3)}")
    return 0


if __name__ == "__main__":
    main()
